//! Analytical device cost model — the §3.2 formulation of the paper, used
//! two ways:
//!
//! 1. **Theory curves** (Fig. 3): the closed-form speedup η(k, α, s, B, M)
//!    under the paper's H100 latency functions.
//! 2. **Simulated-time accounting** (Figs. 13/14, Table 2): the Rust
//!    engine emits real per-iteration schedules (which rows drafted,
//!    which verified, how many KV bytes each touched); this module converts
//!    them into H100-calibrated iteration times.  This is the documented
//!    substitution for not having an H100: *schedules are real, the clock
//!    is modelled* — scheduling-policy comparisons therefore reproduce the
//!    paper's who-wins shapes under the paper's own latency model.
//!
//! Latency model (paper §2.1):
//!   T_GEMM(B): near-constant below the saturation point B̂, then linear.
//!   T_Attn(M): linear in the total KV bytes M touched.

/// Calibration constants.  Defaults approximate an H100 SXM5 serving a
/// Qwen3-8B-shaped model (the paper's Fig. 2/Table 2 operating point).
#[derive(Clone, Debug)]
pub struct DeviceModel {
    /// HBM bandwidth usable by attention (bytes/s).
    pub hbm_bw: f64,
    /// GEMM saturation point (token rows per step).
    pub b_hat: f64,
    /// GEMM latency in the flat (weight-bound) region (s) — time to stream
    /// the weights once.
    pub t_gemm_flat: f64,
    /// Incremental GEMM cost per token row past saturation (s/row).
    pub t_gemm_per_row: f64,
    /// Fixed per-kernel-launch overhead (s) — drives the Fig. 15 fused-vs-
    /// sequential comparison.
    pub t_launch: f64,
    /// CPU scheduling overhead per iteration when NOT overlapped (s);
    /// the paper's Table 2 measures 3.2 ms for vLLM.
    pub t_cpu_sync: f64,
    /// Host<->device (PCIe) bandwidth for KV offload (bytes/s).
    pub pcie_bw: f64,
}

impl Default for DeviceModel {
    fn default() -> Self {
        DeviceModel {
            hbm_bw: 3.0e12,          // ~3 TB/s effective
            b_hat: 256.0,            // §3.2: "B̂=256 only incurs minimal latency increase"
            t_gemm_flat: 5.0e-3,     // weight streaming floor for the 8B model
            t_gemm_per_row: 2.0e-5,  // past saturation
            t_launch: 5.0e-6,
            t_cpu_sync: 3.2e-3,      // vLLM CPU overhead, Table 2
            pcie_bw: 55.0e9,         // PCIe gen5 x16 practical
        }
    }
}

impl DeviceModel {
    /// T_GEMM(B): flat below B̂ (weight-loading bound), linear above.
    pub fn t_gemm(&self, rows: f64) -> f64 {
        if rows <= 0.0 {
            0.0
        } else if rows <= self.b_hat {
            self.t_gemm_flat
        } else {
            self.t_gemm_flat + (rows - self.b_hat) * self.t_gemm_per_row
        }
    }

    /// T_Attn(M): linear in bytes of KV touched.
    pub fn t_attn(&self, kv_bytes: f64) -> f64 {
        kv_bytes / self.hbm_bw
    }

    /// One iteration over a mixed batch.
    pub fn t_iteration(&self, gemm_rows: f64, kv_bytes: f64, launches: u32) -> f64 {
        self.t_gemm(gemm_rows) + self.t_attn(kv_bytes) + self.t_launch * launches as f64
    }

    /// Offload time for `bytes` of KV over PCIe (chunked, asynchronous —
    /// the *budgeted* time the copier thread needs; Fig. 5 overhead check).
    pub fn t_offload(&self, bytes: f64) -> f64 {
        bytes / self.pcie_bw
    }
}

/// Scale factors mapping this testbed's schedules to the paper's H100
/// operating point (Qwen3-8B, batch 128, ~4-8K contexts).  The engine's
/// simulated clock multiplies its *measured* per-iteration GEMM rows and
/// KV bytes by these before applying the latency model, so scheduling
/// and speculation trade-offs are evaluated in the regime the paper
/// studies (attention 17 ms vs GEMM 7 ms per step, B̂ at ~2× the uniform
/// mixed-batch row count) rather than at toy scale where the weight-
/// streaming floor would swamp everything.
#[derive(Clone, Copy, Debug)]
pub struct SimScale {
    pub gemm_rows: f64,
    pub kv_bytes: f64,
}

impl SimScale {
    /// slots -> paper batch (128 requests); testbed full-batch KV foot-
    /// print (~12 slots x ~260 ctx x 2 KiB) -> the paper's 63 GB touched.
    pub fn paper_scale(slots: usize, kv_bytes_per_token: usize) -> SimScale {
        let batch_scale = 128.0 / slots as f64;
        let testbed_full = slots as f64 * 260.0 * kv_bytes_per_token as f64;
        SimScale {
            gemm_rows: batch_scale,
            kv_bytes: 63.0e9 / testbed_full,
        }
    }

    /// Identity scale (report raw testbed numbers).
    pub fn raw() -> SimScale {
        SimScale { gemm_rows: 1.0, kv_bytes: 1.0 }
    }
}

/// The §3.2 closed-form speedup of sparse self-speculative decoding.
#[derive(Clone, Debug)]
pub struct SpeedupModel {
    pub device: DeviceModel,
    /// Concurrent requests.
    pub batch: f64,
    /// Total KV bytes across the batch.
    pub kv_bytes: f64,
}

impl SpeedupModel {
    /// Baseline per-token latency: T_GEMM(B) + T_Attn(M).
    pub fn t_base(&self) -> f64 {
        self.device.t_gemm(self.batch) + self.device.t_attn(self.kv_bytes)
    }

    /// Per-accepted-token latency with speculation (paper's simplified
    /// form):  (k+1)/(kα+1)·T_GEMM((2k+1)/(k+1)·B) + (ks+1)/(kα+1)·T_Attn(M)
    pub fn t_spec(&self, k: f64, alpha: f64, s: f64) -> f64 {
        let gemm = self.device.t_gemm((2.0 * k + 1.0) / (k + 1.0) * self.batch);
        let attn = self.device.t_attn(self.kv_bytes);
        ((k + 1.0) * gemm + (k * s + 1.0) * attn) / (k * alpha + 1.0)
    }

    /// η = T_base / T_spec.
    pub fn speedup(&self, k: f64, alpha: f64, s: f64) -> f64 {
        self.t_base() / self.t_spec(k, alpha, s)
    }
}

/// Roofline-style utilisation split for one iteration (Fig. 2): what
/// fraction of the iteration is attention (bandwidth-bound) vs GEMM.
pub struct UtilSplit {
    pub attn_frac: f64,
    pub gemm_frac: f64,
    pub bw_util: f64,
    pub compute_util: f64,
}

impl DeviceModel {
    /// Fig. 2 style split.  `flops` is the GEMM work of the iteration,
    /// `peak_flops` the device peak.
    pub fn util_split(
        &self,
        gemm_rows: f64,
        kv_bytes: f64,
        flops: f64,
        peak_flops: f64,
    ) -> UtilSplit {
        let tg = self.t_gemm(gemm_rows);
        let ta = self.t_attn(kv_bytes);
        let tot = (tg + ta).max(1e-12);
        UtilSplit {
            attn_frac: ta / tot,
            gemm_frac: tg / tot,
            bw_util: (kv_bytes / self.hbm_bw) / tot,
            compute_util: (flops / peak_flops) / tot,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> SpeedupModel {
        // Paper's running example: Qwen3-8B, batch 128, ~8K contexts:
        // attention ~21 ms per step => M = 21e-3 * 3e12 = 63 GB touched.
        SpeedupModel {
            device: DeviceModel::default(),
            batch: 128.0,
            kv_bytes: 63.0e9,
        }
    }

    #[test]
    fn gemm_flat_then_linear() {
        let d = DeviceModel::default();
        assert_eq!(d.t_gemm(1.0), d.t_gemm(200.0));
        assert!(d.t_gemm(512.0) > d.t_gemm(256.0));
        assert_eq!(d.t_gemm(0.0), 0.0);
    }

    #[test]
    fn attention_reduction_matches_paper_example() {
        // §3.2: k=16, α=0.75, s=0.05 — attention latency cut (kα+1)/(ks+1).
        let (k, alpha, s) = (16.0f64, 0.75, 0.05);
        let reduction = (k * alpha + 1.0) / (k * s + 1.0);
        assert!(reduction > 6.0 && reduction < 8.0, "reduction={reduction}");
    }

    #[test]
    fn speedup_positive_and_bounded() {
        let m = model();
        let eta = m.speedup(8.0, 0.77, 0.05);
        assert!(eta > 1.5, "eta={eta}");
        // Bounded by the attention reduction ratio (+1 slack for GEMM).
        assert!(eta < (8.0 * 0.77 + 1.0) / (8.0 * 0.05 + 1.0) + 1.0);
    }

    #[test]
    fn speedup_monotone_in_alpha_and_sparsity() {
        let m = model();
        assert!(m.speedup(8.0, 0.8, 0.05) > m.speedup(8.0, 0.4, 0.05));
        assert!(m.speedup(8.0, 0.8, 0.05) > m.speedup(8.0, 0.8, 0.5));
    }

    #[test]
    fn no_speedup_when_draft_no_better_than_dense() {
        let m = model();
        let eta = m.speedup(8.0, 0.05, 0.05);
        assert!(eta < 1.05, "eta={eta}");
    }

    #[test]
    fn unified_vs_naive_schedule_shape() {
        // §3.3 workload fluctuation: naive = k small GEMMs + 1 big GEMM;
        // unified = k+1 medium GEMMs.  Past saturation the big GEMM hurts.
        let d = DeviceModel::default();
        let (b, k) = (128.0, 8.0);
        let naive = k * d.t_gemm(b) + d.t_gemm((k + 1.0) * b);
        let unified = (k + 1.0) * d.t_gemm((2.0 * k + 1.0) / (k + 1.0) * b);
        assert!(unified < naive, "unified={unified} naive={naive}");
    }

    #[test]
    fn util_split_attention_dominates_long_context() {
        let d = DeviceModel::default();
        let u = d.util_split(128.0, 63.0e9, 2.0e12, 989e12);
        assert!(u.attn_frac > 0.7, "attn_frac={}", u.attn_frac);
    }
}
