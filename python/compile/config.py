"""Model + grammar configuration shared by the compile path and (via
artifacts/config.json) the Rust coordinator.

This is the single source of truth for every compile-time shape in the
three-layer stack.  The Rust side never imports this module — `aot.py`
serialises it into ``artifacts/config.json`` which ``rust/src/model``
parses at startup.

Scale note (documented substitution, see DESIGN.md §1): the paper serves
Qwen3-1.7B/8B/14B on DGX-H100; this reproduction serves a Qwen3-*shaped*
~0.7M-parameter model on the CPU PJRT client.  Every architectural trait
that matters to SparseSpec is preserved: GQA (grouped query attention),
RoPE, RMSNorm, SwiGLU, page-size-1 paged KV, and the draft/verify split.
"""

from dataclasses import dataclass, asdict, field
import json


@dataclass(frozen=True)
class ModelConfig:
    """Qwen3-shaped decoder-only transformer, scaled to build-time-trainable."""

    vocab: int = 512
    hidden: int = 128
    layers: int = 4
    q_heads: int = 4
    kv_heads: int = 2
    head_dim: int = 32
    ffn: int = 256
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5

    # Serving shapes (compile-time static for AOT).
    max_seq: int = 512        # T: KV-cache positions per slot
    slots: int = 12           # S: device KV slots == max concurrent batch
    prompt_pad: int = 32      # P: prompt chunk length for the prefill artifact

    # Speculation shapes.
    spec_k: int = 8           # default draft length -> verify Q = k+1
    draft_budget: int = 64    # W: default PillarAttn token budget per (layer, kv-head)

    # Sensitivity-sweep artifact variants (Fig. 12 right).
    # Q=1 is the vanilla autoregressive baseline (dense decode, one token).
    verify_q_variants: tuple = (1, 5, 9, 13, 17, 21)   # k in {0, 4, 8, 12, 16, 20}
    draft_w_variants: tuple = (16, 32, 64, 128, 256)

    @property
    def group(self) -> int:
        return self.q_heads // self.kv_heads

    @property
    def q_dim(self) -> int:
        return self.q_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.kv_heads * self.head_dim


@dataclass(frozen=True)
class EagleConfig:
    """EAGLE-like trained draft head (Fig. 11 baseline): a tiny MLP LM over a
    fixed window of the last `ctx` token embeddings, distilled from the target
    model's next-token distribution at build time."""

    ctx: int = 4
    embed: int = 32
    hidden: int = 128


@dataclass(frozen=True)
class GrammarConfig:
    """The synthetic "reasoning trace" language (pointer-chasing grammar).

    Sequences interleave long-range variable lookups (the *pillars* —
    definitions placed near the start that later queries must attend to)
    with locally-predictable filler chains.  This reproduces the paper's
    context-dynamics regime: attention mass concentrates on a small,
    *shifting* set of critical tokens, so oracle-top-k / PillarAttn keep a
    high acceptance rate while a sliding window loses exactly the lookups.

    Token map (vocab 512):
      0 PAD | 1 BOS | 2 EOS | 3 DEF | 4 QRY | 5 EQ | 6 SEP
      16..16+n_slots-1          slot names
      80..80+n_values-1         value tokens
      336..336+n_filler-1       filler tokens (mode-keyed affine chains)
      456..456+n_modes-1        mode tokens (select the filler chain map)

    Two properties matter for reproducing the paper's regime:
      * **temporal locality of critical tokens** — query blocks target a
        slowly-drifting *focus* slot (reasoning keeps working with the
        same variables for a while), so the verification-stride score
        reuse of PillarAttn can capture the relevant definitions;
      * **surface variability** — filler chains are keyed by a per-run
        mode token, so short suffixes rarely recur verbatim and the
        N-gram baseline cannot simply copy (matching the paper's finding
        that n-gram drafting degrades on reasoning outputs), while the
        *model* learns the 12 affine maps easily.
    """

    pad: int = 0
    bos: int = 1
    eos: int = 2
    def_tok: int = 3
    qry: int = 4
    eq: int = 5
    sep: int = 6
    slot_base: int = 16
    n_slots: int = 48
    value_base: int = 80
    n_values: int = 256
    filler_base: int = 336
    n_filler: int = 120
    mode_base: int = 456
    n_modes: int = 12
    n_defs: int = 8           # definitions per sequence (the pillars)
    redefine_prob: float = 0.08   # defs are occasionally re-issued mid-body
    query_prob: float = 0.30      # probability a block is a query block
    focus_query_prob: float = 0.85  # queries hit the focus slot this often
    focus_switch_prob: float = 0.18 # focus drifts after a query block

    # per-mode chain constants: step j of a run advances by (a_m + j), so
    # the successor depends on the mode AND the position inside the run —
    # a circuit that must read the (local) mode/run-start tokens rather
    # than copy from a previous occurrence of the same filler elsewhere
    # (induction-style copying would need per-token-moving critical sets,
    # which no strided score reuse can track; real text is not that
    # adversarial).
    mode_mul: tuple = (1, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43)
    mode_add: tuple = (3, 8, 1, 14, 5, 11, 2, 7, 9, 4, 13, 6)

    def filler_next(self, t: int, mode: int, j: int) -> int:
        i = t - self.filler_base
        return self.filler_base + (i + self.mode_mul[mode] + j) % self.n_filler


@dataclass(frozen=True)
class TrainConfig:
    # seq must cover the serving context window (max_seq=512): training at
    # shorter lengths leaves RoPE extrapolation territory where attention
    # goes diffuse and sparse/full agreement collapses (observed: alpha
    # 0.17 at 300-token contexts when trained at seq=160).
    steps: int = 500
    batch: int = 5
    seq: int = 480
    # attention-concentration regulariser weight (see model.make_train_forward)
    attn_entropy_lambda: float = 0.05
    lr: float = 3e-3
    warmup: int = 30
    seed: int = 1234
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    adam_eps: float = 1e-8
    # EAGLE head distillation
    eagle_steps: int = 250
    eagle_batch: int = 32
    eagle_lr: float = 2e-3


MODEL = ModelConfig()
EAGLE = EagleConfig()
GRAMMAR = GrammarConfig()
TRAIN = TrainConfig()


def export_json() -> str:
    """Serialise everything the Rust coordinator needs into one JSON doc."""
    doc = {
        "model": asdict(MODEL),
        "eagle": asdict(EAGLE),
        "grammar": asdict(GRAMMAR),
        "train": {"steps": TRAIN.steps, "seed": TRAIN.seed},
    }
    return json.dumps(doc, indent=2)


if __name__ == "__main__":
    print(export_json())
