//! PillarAttn critical-token selection (§4.1).
//!
//! The verification kernel dumps, per (layer, kv-head), the attention mass
//! each cache position received from the verified queries (averaged over
//! the query-head group) — at zero extra memory passes, since the dense
//! kernel computes those probabilities anyway.  This module turns one dump
//! into the index sets the next k draft steps attend to:
//!
//!   indices(l, h) = sinks ∪ recent-window ∪ Top-K(dump[l, h], rest)
//!
//! mirroring `python/compile/kernels/ref.py::topk_ids_ref` exactly (the
//! cross-language golden test lives in python/tests/test_pillar.py).
//!
//! Selection sits on the drafting critical path — it runs once per
//! (layer, kv-head) on every verification and every draft composition —
//! so the implementation is zero-allocation in steady state:
//!
//! * `select_into` partial-selects the top-k with `select_nth_unstable_by`
//!   (O(T) expected) instead of a full sort, and needs no membership test:
//!   the sinks `[0, s)` and the recent window `[lo, len)` are contiguous
//!   ranges, so the top-k candidate pool is exactly the gap `[s, lo)`.
//! * `PillarState` owns reusable scratch buffers and writes straight into
//!   the engine's flattened index buffer via `refresh_from`/`compose_into`;
//!   `compose`/`topk_indices` remain as allocating thin wrappers for tests.
//! * `refresh_parallel` fans the per-(layer, head) selections out over the
//!   engine's `util::threadpool`.
//!
//! Throughput numbers for the rewrite are tracked by the `pillar_select`
//! bench (`cargo bench -- pillar_select`) and recorded in
//! EXPERIMENTS.md §Perf.

use crate::util::threadpool::ThreadPool;

/// How a drafter composes its per-(layer, head) index set.
#[derive(Clone, Copy, Debug)]
pub struct IndexPolicy {
    /// Total entries per (layer, head) — must equal the artifact's W.
    pub budget: usize,
    /// Leading positions always kept (attention sinks).
    pub sinks: usize,
    /// Trailing window always kept (needed so freshly drafted tokens are
    /// attendable; also the entire mechanism of the MagicDec baseline).
    pub recent: usize,
}

impl IndexPolicy {
    pub fn pillar(budget: usize) -> Self {
        // Paper-style split: a few sinks, a modest local window, the bulk
        // of the budget to dump-selected critical tokens.  (recent=W/2 was
        // tried during the perf pass and measured *worse* — α 0.45 → 0.33
        // — the dump top-k carries more predictive mass than extra window;
        // see EXPERIMENTS.md §Perf.)
        let sinks = 4.min(budget / 8);
        let recent = (budget / 4).max(8).min(budget - sinks);
        IndexPolicy { budget, sinks, recent }
    }

    /// Sliding-window policy (MagicDec / StreamingLLM): no score-selected
    /// tokens at all — everything after the sinks is the recent window.
    pub fn window(budget: usize) -> Self {
        let sinks = 4.min(budget / 8);
        IndexPolicy { budget, sinks, recent: budget - sinks }
    }
}

/// Reusable candidate buffer for `select_into`.  After warm-up no call
/// allocates: the buffer's capacity converges to the largest candidate
/// pool seen so far.
#[derive(Clone, Debug, Default)]
pub struct SelectScratch {
    cand: Vec<i32>,
}

impl SelectScratch {
    /// Current capacity of the candidate buffer (steady-state alloc tests).
    pub fn capacity(&self) -> usize {
        self.cand.capacity()
    }
}

/// Build one (layer, head) index set into `out` (length `policy.budget`):
/// exactly `policy.budget` entries, ascending, -1-padded at the tail.
/// Returns the number of valid (non-negative) entries.
///
/// `scores[t]` is the dumped attention mass for position t (ignored for
/// the slots covered by sinks/recent); `len` is the current valid context
/// length (`len <= scores.len()`).
pub fn select_into(
    scores: &[f32],
    len: usize,
    policy: &IndexPolicy,
    scratch: &mut SelectScratch,
    out: &mut [i32],
) -> usize {
    let budget = policy.budget;
    debug_assert_eq!(out.len(), budget);
    debug_assert!(len <= scores.len());
    // The always-kept set is two contiguous ranges: sinks [0, s_eff) and
    // the recent window [lo, len).  Everything strictly between them is a
    // top-k candidate — no membership test needed.
    let s_eff = policy.sinks.min(len);
    let lo = len.saturating_sub(policy.recent).max(s_eff);
    let n_fixed = s_eff + (len - lo);
    let mut n = 0usize;
    for t in 0..s_eff.min(budget) {
        out[n] = t as i32;
        n += 1;
    }
    if n_fixed >= budget {
        // The fixed set alone fills the budget; the window tail is dropped
        // (ascending order is already established, so no sort needed).
        for t in lo..len {
            if n >= budget {
                break;
            }
            out[n] = t as i32;
            n += 1;
        }
        for o in out[n..].iter_mut() {
            *o = -1;
        }
        return n;
    }
    let rest = budget - n_fixed;
    let pool = lo - s_eff;
    if rest > 0 && pool > 0 {
        let k = rest.min(pool);
        let cand = &mut scratch.cand;
        cand.clear();
        cand.extend(s_eff as i32..lo as i32);
        // Score-descending with stable lowest-index-wins tie rule — the
        // same total order ref.py::topk_ids_ref sorts by, so the partial
        // selection picks an identical top-k set.
        let by_score = |a: &i32, b: &i32| {
            let (sa, sb) = (scores[*a as usize], scores[*b as usize]);
            sb.partial_cmp(&sa)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(b))
        };
        if k < pool {
            cand.select_nth_unstable_by(k, by_score);
        }
        for &c in &cand[..k] {
            out[n] = c;
            n += 1;
        }
    }
    for t in lo..len {
        out[n] = t as i32;
        n += 1;
    }
    out[..n].sort_unstable();
    for o in out[n..].iter_mut() {
        *o = -1;
    }
    n
}

/// Allocating wrapper around `select_into` (tests / one-off callers).
pub fn topk_indices(scores: &[f32], len: usize, policy: &IndexPolicy) -> Vec<i32> {
    let mut out = vec![0i32; policy.budget];
    let mut scratch = SelectScratch::default();
    select_into(scores, len, policy, &mut scratch, &mut out);
    out
}

/// Per-request PillarAttn state: the frozen critical sets from the last
/// verification, refreshed every stride (= every verify).
#[derive(Clone, Debug)]
pub struct PillarState {
    pub layers: usize,
    pub kv_heads: usize,
    pub policy: IndexPolicy,
    /// Frozen critical tokens, flattened [layers * kv_heads, budget]: each
    /// row is an ascending valid prefix with a -1 tail.  Only the last
    /// refresh's selection lives here; sinks+recent are recomputed per
    /// compose so new tokens enter the window.
    critical: Vec<i32>,
    /// Selection scratch for the serial paths.
    scratch: SelectScratch,
    /// One scratch per worker chunk for `refresh_parallel`.
    par_scratch: Vec<SelectScratch>,
}

impl PillarState {
    pub fn new(layers: usize, kv_heads: usize, policy: IndexPolicy) -> Self {
        PillarState {
            layers,
            kv_heads,
            policy,
            critical: vec![-1; layers * kv_heads * policy.budget],
            scratch: SelectScratch::default(),
            par_scratch: Vec::new(),
        }
    }

    fn heads(&self) -> usize {
        self.layers * self.kv_heads
    }

    /// Refresh from a verification dump slice for this request:
    /// `dump` is [L, Hkv, T] flattened; positions >= `len` are stale
    /// (rejected drafts / old garbage) and are excluded.
    ///
    /// Zero heap allocation in steady state: selections land in the
    /// flattened `critical` rows through the reused scratch buffer.
    pub fn refresh_from(&mut self, dump: &[f32], t_dim: usize, len: usize) {
        let w = self.policy.budget;
        let policy = self.policy;
        let len = len.min(t_dim);
        for lh in 0..self.heads() {
            let scores = &dump[lh * t_dim..(lh + 1) * t_dim];
            select_into(
                scores,
                len,
                &policy,
                &mut self.scratch,
                &mut self.critical[lh * w..(lh + 1) * w],
            );
        }
    }

    /// Back-compat name for `refresh_from` (tests, oracle paths).
    pub fn refresh(&mut self, dump: &[f32], t_dim: usize, len: usize) {
        self.refresh_from(dump, t_dim, len);
    }

    /// `refresh_from`, fanned out across (layer, head) chunks on `pool`.
    /// Must be called from outside the pool's own workers (the barrier
    /// would otherwise self-deadlock).  Results are identical to the
    /// serial path — every row's selection is independent.
    ///
    /// Note: the fan-out boxes `n_chunks` closures per call, so unlike
    /// `refresh_from` this path is small-allocation, not zero-allocation.
    /// It is used where wallclock dominates that cost (many-head
    /// refreshes in the oracle drafter and the bench), while the per-slot
    /// verify jobs — already parallel across slots — use `refresh_from`.
    pub fn refresh_parallel(
        &mut self,
        dump: &[f32],
        t_dim: usize,
        len: usize,
        pool: &ThreadPool,
    ) {
        let heads = self.heads();
        let n_chunks = pool.workers().min(heads);
        if n_chunks <= 1 {
            return self.refresh_from(dump, t_dim, len);
        }
        let w = self.policy.budget;
        let policy = self.policy;
        let len = len.min(t_dim);
        if self.par_scratch.len() < n_chunks {
            self.par_scratch.resize_with(n_chunks, SelectScratch::default);
        }
        let rows_per = (heads + n_chunks - 1) / n_chunks;
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(n_chunks);
        for (ci, (chunk, scratch)) in self
            .critical
            .chunks_mut(rows_per * w)
            .zip(self.par_scratch.iter_mut())
            .enumerate()
        {
            let base = ci * rows_per;
            jobs.push(Box::new(move || {
                for (r, row) in chunk.chunks_mut(w).enumerate() {
                    let lh = base + r;
                    let scores = &dump[lh * t_dim..(lh + 1) * t_dim];
                    select_into(scores, len, &policy, scratch, row);
                }
            }));
        }
        pool.scope(jobs);
    }

    /// Compose the index sets for a draft step at current length `len`
    /// directly into `out` — the engine's flattened [L, Hkv, W] index
    /// buffer — with no intermediate allocation.  Each (layer, head) row
    /// is exactly `budget` entries, ascending, -1-padded.
    ///
    /// (The drafted token sits at position len-1 after its KV write; the
    /// engine passes len and the recent window must include it.)
    pub fn compose_into(&self, out: &mut [i32], len: usize) {
        let w = self.policy.budget;
        debug_assert_eq!(out.len(), self.heads() * w);
        let s_eff = self.policy.sinks.min(len);
        let lo = len.saturating_sub(self.policy.recent).max(s_eff);
        for lh in 0..self.heads() {
            let crit = &self.critical[lh * w..(lh + 1) * w];
            let set = &mut out[lh * w..(lh + 1) * w];
            let mut n = 0usize;
            // sinks
            for t in 0..s_eff.min(w) {
                set[n] = t as i32;
                n += 1;
            }
            // recent window (always includes the newest positions, so
            // tokens drafted since the last verification are visible)
            for t in lo..len {
                if n >= w {
                    break;
                }
                set[n] = t as i32;
                n += 1;
            }
            // frozen critical tokens: already-present entries are exactly
            // those in the sink range [0, s_eff) or the window [lo, len),
            // so two range checks replace a hash-set membership test.
            for &c in crit {
                if n >= w || c < 0 {
                    break;
                }
                let cu = c as usize;
                if cu >= s_eff && cu < lo {
                    set[n] = c;
                    n += 1;
                }
            }
            set[..n].sort_unstable();
            for o in set[n..].iter_mut() {
                *o = -1;
            }
        }
    }

    /// Allocating wrapper around `compose_into` (tests / one-off callers).
    /// Output: [L, Hkv, W] flattened, -1 padded, each ascending.
    pub fn compose(&self, len: usize) -> Vec<i32> {
        let mut out = vec![0i32; self.heads() * self.policy.budget];
        self.compose_into(&mut out, len);
        out
    }
}

/// Seed-era selection pipeline (full O(T log T) sort, `HashSet` dedup,
/// per-call `Vec`s), kept verbatim as the *executable specification*: the
/// `pillar_select` bench baseline and the equivalence property tests both
/// use this single copy, so the two can't drift apart.  Mirrors
/// `ref.py::topk_ids_ref`.  Not for production use.
#[doc(hidden)]
pub mod reference {
    use super::IndexPolicy;

    pub fn topk_indices(scores: &[f32], len: usize, policy: &IndexPolicy) -> Vec<i32> {
        let budget = policy.budget;
        let mut chosen: Vec<i32> = Vec::with_capacity(budget);
        for t in 0..policy.sinks.min(len) {
            chosen.push(t as i32);
        }
        let lo = len.saturating_sub(policy.recent);
        for t in lo..len {
            if t >= policy.sinks {
                chosen.push(t as i32);
            }
        }
        chosen.truncate(budget);
        let rest = budget - chosen.len();
        if rest > 0 && len > 0 {
            let taken: std::collections::HashSet<i32> = chosen.iter().copied().collect();
            let mut cand: Vec<i32> = (0..len as i32).filter(|t| !taken.contains(t)).collect();
            cand.sort_by(|&a, &b| {
                let (sa, sb) = (scores[a as usize], scores[b as usize]);
                sb.partial_cmp(&sa)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            });
            chosen.extend(cand.into_iter().take(rest));
        }
        chosen.sort_unstable();
        chosen.resize(budget, -1);
        chosen
    }

    pub struct Pillar {
        pub layers: usize,
        pub kv_heads: usize,
        pub policy: IndexPolicy,
        critical: Vec<Vec<i32>>,
    }

    impl Pillar {
        pub fn new(layers: usize, kv_heads: usize, policy: IndexPolicy) -> Self {
            Pillar { layers, kv_heads, policy, critical: vec![Vec::new(); layers * kv_heads] }
        }

        pub fn refresh(&mut self, dump: &[f32], t_dim: usize, len: usize) {
            for l in 0..self.layers {
                for h in 0..self.kv_heads {
                    let off = (l * self.kv_heads + h) * t_dim;
                    let scores = &dump[off..off + t_dim];
                    let ids = topk_indices(scores, len.min(t_dim), &self.policy);
                    let slot = &mut self.critical[l * self.kv_heads + h];
                    slot.clear();
                    slot.extend(ids.iter().copied().filter(|&x| x >= 0));
                }
            }
        }

        pub fn compose(&self, len: usize) -> Vec<i32> {
            let w = self.policy.budget;
            let mut out = Vec::with_capacity(self.layers * self.kv_heads * w);
            for l in 0..self.layers {
                for h in 0..self.kv_heads {
                    let crit = &self.critical[l * self.kv_heads + h];
                    let mut set: Vec<i32> = Vec::with_capacity(w);
                    for t in 0..self.policy.sinks.min(len) {
                        set.push(t as i32);
                    }
                    let lo = len.saturating_sub(self.policy.recent);
                    for t in lo..len {
                        if t >= self.policy.sinks {
                            set.push(t as i32);
                        }
                    }
                    let have: std::collections::HashSet<i32> = set.iter().copied().collect();
                    for &c in crit {
                        if set.len() >= w {
                            break;
                        }
                        if (c as usize) < len && !have.contains(&c) {
                            set.push(c);
                        }
                    }
                    set.truncate(w);
                    set.sort_unstable();
                    set.resize(w, -1);
                    out.extend(set);
                }
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ptest;

    fn policy() -> IndexPolicy {
        IndexPolicy { budget: 16, sinks: 2, recent: 4 }
    }

    #[test]
    fn topk_selects_highest_scores() {
        let mut scores = vec![0.0f32; 64];
        scores[30] = 0.9;
        scores[45] = 0.8;
        scores[10] = 0.7;
        let ids = topk_indices(&scores, 64, &policy());
        assert_eq!(ids.len(), 16);
        // sinks 0,1; recent 60..64; top includes 30, 45, 10
        assert!(ids.contains(&0) && ids.contains(&1));
        for t in 60..64 {
            assert!(ids.contains(&(t as i32)), "recent {t} missing");
        }
        for t in [30, 45, 10] {
            assert!(ids.contains(&(t as i32)), "critical {t} missing");
        }
    }

    #[test]
    fn short_context_pads_with_holes() {
        let scores = vec![0.1f32; 8];
        let ids = topk_indices(&scores, 5, &policy());
        let valid: Vec<i32> = ids.iter().copied().filter(|&x| x >= 0).collect();
        assert_eq!(valid, vec![0, 1, 2, 3, 4]);
        assert!(ids[5..].iter().all(|&x| x == -1));
    }

    ptest!(topk_invariants, |g| {
        let len = g.usize(0, 256);
        let budget = g.usize(4, 64);
        let sinks = g.usize(0, budget / 4);
        let recent = g.usize(1, budget - sinks);
        let policy = IndexPolicy { budget, sinks, recent };
        let scores: Vec<f32> = (0..256).map(|_| g.f64(0.0, 1.0) as f32).collect();
        let ids = topk_indices(&scores, len, &policy);
        assert_eq!(ids.len(), budget);
        // valid prefix, -1 suffix
        let valid: Vec<i32> = ids.iter().copied().filter(|&x| x >= 0).collect();
        let n_valid = valid.len();
        assert!(ids[..n_valid].iter().all(|&x| x >= 0));
        assert!(ids[n_valid..].iter().all(|&x| x == -1));
        // ascending, unique, in range
        for w in valid.windows(2) {
            assert!(w[0] < w[1], "not strictly ascending: {ids:?}");
        }
        assert!(valid.iter().all(|&x| (x as usize) < len.max(1)));
        // count = min(budget, len)
        assert_eq!(n_valid, budget.min(len));
        // newest token always present when len > 0
        if len > 0 && budget > 0 {
            assert!(valid.contains(&(len as i32 - 1)));
        }
    });

    #[test]
    fn state_refresh_and_compose() {
        let mut st = PillarState::new(2, 2, policy());
        let t = 64;
        let mut dump = vec![0.0f32; 2 * 2 * t];
        // layer 0 head 0: position 33 is critical
        dump[33] = 1.0;
        // layer 1 head 1 (row l*kv_heads + h = 3): position 7 is critical
        dump[3 * t + 7] = 1.0;
        st.refresh(&dump, t, 50);
        let idx = st.compose(50);
        assert_eq!(idx.len(), 2 * 2 * 16);
        let l0h0 = &idx[0..16];
        assert!(l0h0.contains(&33), "l0h0={l0h0:?}");
        let l1h1 = &idx[3 * 16..4 * 16];
        assert!(l1h1.contains(&7), "l1h1={l1h1:?}");
        // stale positions beyond len excluded
        assert!(idx.iter().all(|&x| x < 50));
    }

    #[test]
    fn compose_includes_new_positions_between_refreshes() {
        let mut st = PillarState::new(1, 1, policy());
        let t = 64;
        let dump = vec![0.0f32; t];
        st.refresh(&dump, t, 20);
        // context grew to 24 since the refresh (4 drafted tokens)
        let idx = st.compose(24);
        for p in 20..24 {
            assert!(idx.contains(&(p as i32)), "drafted position {p} missing");
        }
    }

    #[test]
    fn window_policy_is_pure_window() {
        let p = IndexPolicy::window(16);
        let mut scores = vec![0.0f32; 128];
        scores[50] = 100.0; // huge score must be IGNORED by window policy
        let ids = topk_indices(&scores, 100, &p);
        let valid: Vec<i32> = ids.iter().copied().filter(|&x| x >= 0).collect();
        assert_eq!(valid.len(), 16);
        // sinks + last 12: position 50 not included
        assert!(!valid.contains(&50));
        assert!(valid.contains(&99));
    }

    #[test]
    fn compose_into_matches_compose() {
        let mut st = PillarState::new(2, 3, policy());
        let t = 96;
        let dump: Vec<f32> = (0..2 * 3 * t).map(|i| ((i * 37) % 101) as f32).collect();
        st.refresh_from(&dump, t, 80);
        for len in [5usize, 20, 80, 84] {
            let via_vec = st.compose(len);
            let mut direct = vec![7i32; 2 * 3 * 16];
            st.compose_into(&mut direct, len);
            assert_eq!(via_vec, direct, "len={len}");
        }
    }

    /// Acceptance gate: after warm-up, repeated refresh/compose cycles
    /// must not reallocate — capacities stay frozen across calls.
    #[test]
    fn steady_state_capacities_are_stable() {
        let layers = 2;
        let kv_heads = 2;
        let t = 512;
        let mut st = PillarState::new(layers, kv_heads, policy());
        let dump: Vec<f32> = (0..layers * kv_heads * t)
            .map(|i| ((i * 13) % 251) as f32)
            .collect();
        let mut out = vec![0i32; layers * kv_heads * 16];
        // Warm up at the largest length this test will ever use.
        st.refresh_from(&dump, t, t);
        st.compose_into(&mut out, t);
        let crit_cap = st.critical.capacity();
        let scratch_cap = st.scratch.capacity();
        for i in 0..64 {
            let len = 1 + (i * 41) % t;
            st.refresh_from(&dump, t, len);
            st.compose_into(&mut out, len + 2);
            assert_eq!(st.critical.capacity(), crit_cap, "critical realloc at {i}");
            assert_eq!(st.scratch.capacity(), scratch_cap, "scratch realloc at {i}");
        }
    }

    #[test]
    fn parallel_refresh_matches_serial() {
        let pool = ThreadPool::new(4);
        let layers = 4;
        let kv_heads = 3;
        let t = 128;
        let pol = IndexPolicy::pillar(32);
        let dump: Vec<f32> = (0..layers * kv_heads * t)
            .map(|i| ((i * 29) % 97) as f32 / 97.0)
            .collect();
        let mut serial = PillarState::new(layers, kv_heads, pol);
        let mut parallel = PillarState::new(layers, kv_heads, pol);
        for len in [3usize, 40, 100, 128] {
            serial.refresh_from(&dump, t, len);
            parallel.refresh_parallel(&dump, t, len, &pool);
            assert_eq!(serial.critical, parallel.critical, "len={len}");
        }
    }
}
