# Cross-check of the PR-6 observability tentpole (rust/src/trace/mod.rs,
# rust/src/metrics/registry.rs), per the no-Rust-toolchain verify flow:
# a 1:1 Python port of the span journal -> Chrome/Perfetto exporter and of
# the typed MetricsRegistry, driven through an engine-shaped emission
# sequence (iterations with nested admit/draft/propose/verify spans,
# session lifecycle instants, interleaved async KV offloads, counters).
#
# Pins, mirroring rust/tests/trace.rs and the trace/registry unit suites:
#   1. span-name constants — extracted from rust/src/trace/mod.rs itself,
#      so the twin fails if the Rust `names` module drifts;
#   2. Perfetto trace-event schema — ph letters (X/i/C/b/e/M), one thread
#      lane per track, every event carries args.sim_us, X spans nest
#      properly per lane (proper containment, never partial overlap);
#   3. journal sim timestamps are monotone under a monotone serving clock;
#   4. sampling thins iteration spans but never lifecycle instants; the
#      ring buffer bounds memory, counts drops, and orphaned Begins are
#      skipped rather than corrupting the timeline;
#   5. MetricsRegistry snapshot/merge is associative (counters sum, gauges
#      last-write-wins, histograms concatenate) and the Prometheus/markdown
#      renderings are deterministic.

import copy
import json
import os
import re

# ---------------------------------------------------------------------
# span-name constants, pinned against the Rust source
# ---------------------------------------------------------------------

NAMES = {
    "ITERATION": "iteration",
    "ADMIT": "admit",
    "DRAFT": "draft",
    "PROPOSE": "propose",
    "VERIFY": "verify",
    "DELAYED_VERIFY_OVERLAP": "delayed_verify_overlap",
    "KV_ADMIT": "kv_admit",
    "KV_OFFLOAD": "kv_offload",
    "KV_PREEMPT": "kv_preempt",
    "KV_RELOAD": "kv_reload",
    "KV_FORGET": "kv_forget",
    "BUCKET_ASSIGN": "bucket_assign",
    "ADAPTIVE_K": "adaptive_k",
    "SESSION_SUBMIT": "session_submit",
    "SESSION_FIRST_TOKEN": "session_first_token",
    "SESSION_FINISH": "session_finish",
    # PR 7 robustness events (fault injection / degradation lifecycle)
    "FAULT": "fault",
    "FAULT_RETRY": "fault_retry",
    "SLOT_DEGRADE": "slot_degrade",
    "SLOT_PROMOTE": "slot_promote",
    "SESSION_FAIL": "session_fail",
}

TRACKS = {  # Track::tid() / Track::label()
    "engine": 1,
    "device": 2,
    "scheduler": 3,
    "kv": 4,
    "session": 5,
    "drafter": 6,
    "overlap": 7,
}


def rust_trace_source():
    here = os.path.dirname(os.path.abspath(__file__))
    path = os.path.join(here, "..", "..", "rust", "src", "trace", "mod.rs")
    with open(path) as f:
        return f.read()


def test_span_name_constants_match_rust_names_module():
    src = rust_trace_source()
    rust_names = dict(
        re.findall(r'pub const ([A-Z_]+): &str = "([a-z_]+)";', src)
    )
    assert rust_names == NAMES, "python twin drifted from trace::names"
    # track lanes stay pinned too
    for label, tid in TRACKS.items():
        assert f'Track::{label.capitalize()} => {tid}' in src.replace(
            "Kv =>", "Kv =>"
        ) or re.search(rf"Track::\w+ => {tid},", src), label
        assert f'"{label}"' in src, f"track label {label} missing in Rust"


# ---------------------------------------------------------------------
# Tracer port (rust/src/trace/mod.rs)
# ---------------------------------------------------------------------


class Tracer:
    """1:1 port of trace::Tracer with a deterministic wall clock."""

    def __init__(self, enabled=False, capacity=65_536, sample_every=1):
        self.enabled = enabled
        self.capacity = max(capacity, 16)
        self.sample_every = max(sample_every, 1)
        self.events = []  # (name, kind, track, id, wall_us, sim_us, dur_us, args)
        self.dropped = 0
        self.sampled = False
        self._wall = 0.0

    def now_us(self):
        self._wall += 1.0  # strictly-monotone stand-in for Instant::elapsed
        return self._wall

    def _push(self, ev):
        if len(self.events) >= self.capacity:
            self.events.pop(0)
            self.dropped += 1
        self.events.append(ev)

    def _push_now(self, name, kind, track, id_, sim_s, args):
        self._push((name, kind, track, id_, self.now_us(), sim_s * 1e6, 0.0, args))

    def iter_begin(self, it, sim_s):
        if not self.enabled:
            return
        self.sampled = it % self.sample_every == 0
        if self.sampled:
            self._push_now(NAMES["ITERATION"], "B", "engine", 0, sim_s, {"iter": it})

    def iter_end(self, sim_s, args=None):
        if self.sampled:
            self._push_now(NAMES["ITERATION"], "E", "engine", 0, sim_s, args or {})

    def begin(self, name, track, sim_s):
        if self.sampled:
            self._push_now(name, "B", track, 0, sim_s, {})

    def end(self, name, track, sim_s, args=None):
        if self.sampled:
            self._push_now(name, "E", track, 0, sim_s, args or {})

    def complete_at(self, name, track, wall_us, dur_us, sim_s, args=None):
        if self.sampled:
            self._push((name, "X", track, 0, wall_us, sim_s * 1e6, dur_us, args or {}))

    def instant(self, name, track, sim_s, args=None):
        if self.enabled:
            self._push_now(name, "i", track, 0, sim_s, args or {})

    def counter(self, name, sim_s, value):
        if self.sampled:
            self._push_now(name, "C", "engine", 0, sim_s, {"value": value})

    def async_begin(self, name, track, id_, sim_s, args=None):
        if self.enabled:
            self._push_now(name, "b", track, id_, sim_s, args or {})

    def async_end(self, name, track, id_, sim_s, args=None):
        if self.enabled:
            self._push_now(name, "e", track, id_, sim_s, args or {})

    # -- exporters (mirrors export_chrome / export_jsonl) --------------

    def export_chrome(self):
        out = [
            {
                "name": "process_name",
                "ph": "M",
                "pid": 1,
                "tid": 0,
                "args": {"name": "sparsespec"},
            }
        ]
        for label, tid in TRACKS.items():
            out.append(
                {
                    "name": "thread_name",
                    "ph": "M",
                    "pid": 1,
                    "tid": tid,
                    "args": {"name": label},
                }
            )
        stacks = {tid: [] for tid in TRACKS.values()}
        for name, kind, track, id_, wall, sim, dur, args in self.events:
            tid = TRACKS[track]
            if kind == "B":
                stacks[tid].append((name, wall, sim, args))
            elif kind == "E":
                # unwind to the matching Begin; orphans above it are dropped
                while stacks[tid]:
                    bname, bwall, bsim, bargs = stacks[tid].pop()
                    if bname == name:
                        a = {
                            "sim_us": bsim,
                            "sim_dur_us": max(sim - bsim, 0.0),
                        }
                        a.update(bargs)
                        a.update(args)
                        out.append(
                            {
                                "name": bname,
                                "cat": track,
                                "ph": "X",
                                "pid": 1,
                                "tid": tid,
                                "ts": bwall,
                                "dur": max(wall - bwall, 0.0),
                                "args": a,
                            }
                        )
                        break
            elif kind == "X":
                out.append(
                    {
                        "name": name,
                        "cat": track,
                        "ph": "X",
                        "pid": 1,
                        "tid": tid,
                        "ts": wall,
                        "dur": dur,
                        "args": {"sim_us": sim, **args},
                    }
                )
            elif kind == "i":
                out.append(
                    {
                        "name": name,
                        "cat": track,
                        "ph": "i",
                        "s": "t",
                        "pid": 1,
                        "tid": tid,
                        "ts": wall,
                        "args": {"sim_us": sim, **args},
                    }
                )
            elif kind == "C":
                out.append(
                    {
                        "name": name,
                        "ph": "C",
                        "pid": 1,
                        "tid": tid,
                        "ts": wall,
                        "args": {"sim_us": sim, **args},
                    }
                )
            else:  # b / e
                out.append(
                    {
                        "name": name,
                        "cat": track,
                        "ph": kind,
                        "id": id_,
                        "pid": 1,
                        "tid": tid,
                        "ts": wall,
                        "args": {"sim_us": sim, **args},
                    }
                )
        return {
            "traceEvents": out,
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped},
        }

    def export_jsonl(self):
        lines = []
        for name, kind, track, id_, wall, sim, dur, args in self.events:
            rec = {
                "name": name,
                "kind": kind,
                "track": track,
                "wall_us": wall,
                "sim_us": sim,
            }
            if id_ != 0:
                rec["id"] = id_
            if kind == "X":
                rec["dur_us"] = dur
            if args:
                rec["args"] = args
            lines.append(json.dumps(rec))
        return "\n".join(lines)


def drive_engine_shape(t, iters=6):
    """Emit the event sequence the instrumented engine produces: nested
    phase spans, per-slot lifecycle instants, interleaved KV offloads."""
    sim = 0.0
    for it in range(iters):
        t.iter_begin(it, sim)
        t.begin(NAMES["ADMIT"], "engine", sim)
        if it == 0:
            for rid in (1, 2):
                t.instant(NAMES["SESSION_SUBMIT"], "session", sim, {"req": rid})
                t.instant(NAMES["BUCKET_ASSIGN"], "scheduler", sim, {"req": rid, "bucket": rid % 3})
                t.instant(NAMES["KV_ADMIT"], "kv", sim, {"req": rid, "tokens": 32})
                t.instant(NAMES["SESSION_FIRST_TOKEN"], "session", sim, {"req": rid})
        t.end(NAMES["ADMIT"], "engine", sim, {"admitted": 2 if it == 0 else 0})
        t.begin(NAMES["DRAFT"], "engine", sim)
        t.begin(NAMES["PROPOSE"], "engine", sim)
        t.end(NAMES["PROPOSE"], "engine", sim, {"drafter": "pillar_w64", "slots": 2})
        t.end(NAMES["DRAFT"], "engine", sim, {"w": 64, "slots": 2})
        t.begin(NAMES["VERIFY"], "engine", sim)
        t.end(NAMES["VERIFY"], "engine", sim, {"slots": 2, "delayed": 1})
        if it == 1:
            t.async_begin(NAMES["KV_OFFLOAD"], "kv", 1, sim, {"req": 1, "tokens": 40})
            t.async_begin(NAMES["KV_OFFLOAD"], "kv", 2, sim, {"req": 2, "tokens": 48})
        if it == 3:
            # interleaved (not nested) completion order: 1 then 2
            t.async_end(NAMES["KV_OFFLOAD"], "kv", 1, sim, {"transfer_us": 120.0})
            t.async_end(NAMES["KV_OFFLOAD"], "kv", 2, sim, {"transfer_us": 130.0})
            t.instant(NAMES["KV_RELOAD"], "kv", sim, {"req": 1, "tokens": 40})
        t.complete_at("verify.gemm", "device", t.now_us(), 5.0, sim, {"calls": 1})
        t.counter("queue_depth", sim, float(iters - it))
        t.counter("kv_used_tokens", sim, 100.0 + it)
        sim += 0.002
        t.iter_end(sim, {"launches": 3})
    for rid in (1, 2):
        t.instant(NAMES["SESSION_FINISH"], "session", sim, {"req": rid, "reason": "completed"})


def spans_nest_properly(events, tid):
    """X spans on one lane must be disjoint or properly nested."""
    xs = sorted(
        (
            (e["ts"], e["ts"] + e["dur"])
            for e in events
            if e.get("ph") == "X" and e["tid"] == tid
        ),
    )
    stack = []
    for lo, hi in xs:
        while stack and stack[-1] <= lo:
            stack.pop()
        if stack:
            assert hi <= stack[-1], f"partial overlap: ({lo},{hi}) vs end {stack[-1]}"
        stack.append(hi)


def test_perfetto_export_schema_and_phase_nesting():
    t = Tracer(enabled=True)
    drive_engine_shape(t)
    doc = t.export_chrome()
    # top-level shape
    assert set(doc) == {"traceEvents", "displayTimeUnit", "otherData"}
    assert doc["otherData"]["dropped_events"] == 0
    evs = doc["traceEvents"]
    # metadata names every lane
    lanes = {
        e["args"]["name"]
        for e in evs
        if e["ph"] == "M" and e["name"] == "thread_name"
    }
    assert lanes == set(TRACKS)
    # every non-metadata event: known ph, pid 1, a real lane, args.sim_us
    for e in evs:
        if e["ph"] == "M":
            continue
        assert e["ph"] in ("X", "i", "C", "b", "e"), e
        assert e["pid"] == 1 and e["tid"] in TRACKS.values()
        assert "sim_us" in e["args"], e
    # phase spans folded into X and properly nested on the engine lane
    xnames = [e["name"] for e in evs if e["ph"] == "X"]
    for want in ("iteration", "admit", "draft", "propose", "verify"):
        assert want in xnames, f"missing span {want}"
    spans_nest_properly(evs, TRACKS["engine"])
    # draft strictly contains propose (the begin/end emission order)
    draft = next(e for e in evs if e["ph"] == "X" and e["name"] == "draft")
    prop = next(e for e in evs if e["ph"] == "X" and e["name"] == "propose")
    assert draft["ts"] < prop["ts"]
    assert prop["ts"] + prop["dur"] < draft["ts"] + draft["dur"]
    assert prop["args"]["drafter"] == "pillar_w64"
    # counter shape
    c = next(e for e in evs if e["ph"] == "C" and e["name"] == "queue_depth")
    assert c["args"]["value"] == 6.0
    # instants are thread-scoped
    i = next(e for e in evs if e["ph"] == "i" and e["name"] == "session_submit")
    assert i["s"] == "t" and i["tid"] == TRACKS["session"]
    # async offloads: balanced b/e per id, on the kv lane
    for id_ in (1, 2):
        b = [e for e in evs if e["ph"] == "b" and e.get("id") == id_]
        e_ = [e for e in evs if e["ph"] == "e" and e.get("id") == id_]
        assert len(b) == 1 and len(e_) == 1, f"unbalanced async id {id_}"
        assert b[0]["tid"] == TRACKS["kv"] and e_[0]["ts"] > b[0]["ts"]
    # device complete span carries its explicit duration
    dev = next(e for e in evs if e["ph"] == "X" and e["name"] == "verify.gemm")
    assert dev["dur"] == 5.0 and dev["tid"] == TRACKS["device"]
    # the whole document is valid JSON
    json.loads(json.dumps(doc))


def test_journal_sim_timestamps_are_monotone():
    t = Tracer(enabled=True)
    drive_engine_shape(t, iters=8)
    last = float("-inf")
    seen = 0
    for line in t.export_jsonl().splitlines():
        rec = json.loads(line)
        assert rec["sim_us"] >= last, line
        last = rec["sim_us"]
        seen += 1
    assert seen > 50


def test_sampling_thins_iterations_but_keeps_lifecycle():
    full = Tracer(enabled=True)
    drive_engine_shape(full, iters=8)
    thin = Tracer(enabled=True, sample_every=4)
    drive_engine_shape(thin, iters=8)
    assert len(thin.events) < len(full.events) / 2
    kinds = [(n, k) for n, k, *_ in thin.events]
    # lifecycle instants and async transitions survive sampling
    assert kinds.count((NAMES["SESSION_SUBMIT"], "i")) == 2
    assert kinds.count((NAMES["SESSION_FINISH"], "i")) == 2
    assert kinds.count((NAMES["KV_OFFLOAD"], "b")) == 2
    # iteration spans only on sampled iterations 0 and 4
    assert kinds.count((NAMES["ITERATION"], "B")) == 2
    # disabled tracer journals nothing at all
    off = Tracer(enabled=False)
    drive_engine_shape(off)
    assert off.events == [] and off.dropped == 0


def test_ring_buffer_caps_and_orphans_are_skipped():
    t = Tracer(enabled=True, capacity=64)
    drive_engine_shape(t, iters=40)
    assert len(t.events) == 64
    assert t.dropped > 0
    doc = t.export_chrome()
    assert doc["otherData"]["dropped_events"] == t.dropped
    # Begins whose Ends were evicted must not produce X spans; whatever
    # spans remain still nest properly per lane.
    for tid in TRACKS.values():
        spans_nest_properly(doc["traceEvents"], tid)
    # explicit orphan: a Begin with no End never surfaces, and the
    # enclosing span still pairs across it (the unwind rule)
    t2 = Tracer(enabled=True)
    t2.iter_begin(0, 0.0)
    t2.begin(NAMES["DRAFT"], "engine", 0.0)
    t2.begin(NAMES["VERIFY"], "engine", 0.0)
    t2.end(NAMES["VERIFY"], "engine", 0.0)
    t2.iter_end(0.001)
    xnames = [e["name"] for e in t2.export_chrome()["traceEvents"] if e["ph"] == "X"]
    assert "verify" in xnames and "iteration" in xnames
    assert "draft" not in xnames


# ---------------------------------------------------------------------
# MetricsRegistry port (rust/src/metrics/registry.rs)
# ---------------------------------------------------------------------


def _key(name, labels=()):
    return (name, tuple(sorted(labels)))


def _sanitize(name):
    return "".join(c if c.isalnum() or c in "_:" else "_" for c in name)


def _escape(v):
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt(v):
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(v)


def _percentile(samples, p):
    if not samples:
        return 0.0
    s = sorted(samples)
    rank = round((p / 100.0) * (len(s) - 1))
    return s[min(rank, len(s) - 1)]


class Registry:
    """1:1 port of metrics::MetricsRegistry merge/exposition semantics."""

    def __init__(self):
        self.counters = {}
        self.gauges = {}
        self.histograms = {}  # key -> list of samples

    def inc(self, name, labels=(), by=1.0):
        k = _key(name, labels)
        self.counters[k] = self.counters.get(k, 0.0) + by

    def set_gauge(self, name, labels=(), v=0.0):
        self.gauges[_key(name, labels)] = v

    def observe(self, name, labels=(), v=0.0):
        self.histograms.setdefault(_key(name, labels), []).append(v)

    def snapshot(self):
        return copy.deepcopy(self)

    def merge_from(self, other):
        for k, v in other.counters.items():
            self.counters[k] = self.counters.get(k, 0.0) + v
        for k, v in other.gauges.items():
            self.gauges[k] = v  # last-write-wins
        for k, h in other.histograms.items():
            self.histograms.setdefault(k, []).extend(h)

    def expose_prometheus(self, prefix):
        out = []
        last = None

        def type_line(full, kind):
            nonlocal last
            if last != (full, kind):
                out.append(f"# TYPE {full} {kind}")
                last = (full, kind)

        def block(labels, extra=()):
            parts = [f'{_sanitize(k)}="{_escape(v)}"' for k, v in labels]
            parts += [f'{k}="{_escape(v)}"' for k, v in extra]
            return "{" + ",".join(parts) + "}" if parts else ""

        for (name, labels), v in sorted(self.counters.items()):
            full = f"{_sanitize(prefix)}_{_sanitize(name)}"
            type_line(full, "counter")
            out.append(f"{full}{block(labels)} {_fmt(v)}")
        for (name, labels), v in sorted(self.gauges.items()):
            full = f"{_sanitize(prefix)}_{_sanitize(name)}"
            type_line(full, "gauge")
            out.append(f"{full}{block(labels)} {_fmt(v)}")
        for (name, labels), h in sorted(self.histograms.items()):
            full = f"{_sanitize(prefix)}_{_sanitize(name)}"
            type_line(full, "summary")
            for q, p in (("0.5", 50.0), ("0.99", 99.0)):
                out.append(
                    f"{full}{block(labels, (('quantile', q),))} "
                    f"{_fmt(_percentile(h, p))}"
                )
            out.append(f"{full}_sum{block(labels)} {_fmt(sum(h))}")
            out.append(f"{full}_count{block(labels)} {len(h)}")
        return "\n".join(out) + ("\n" if out else "")


def _mk_registry(seed):
    """A deterministic pseudo-random registry (no random module needed)."""
    r = Registry()
    x = seed * 2654435761 % 2**32
    for i in range(1 + seed % 4):
        x = (x * 1103515245 + 12345) % 2**31
        r.inc("requests_done", (("drafter", f"d{x % 3}"),), float(x % 7))
        r.inc("requests_done", (), 1.0)
        x = (x * 1103515245 + 12345) % 2**31
        r.set_gauge("kv_used_tokens", (), float(x % 1000))
        r.observe("ttft_s", (), (x % 100) / 100.0)
        r.observe("ttft_s", (("drafter", f"d{x % 3}"),), (x % 50) / 100.0)
    return r


def test_registry_merge_is_associative():
    # (a + b) + c == a + (b + c) on every surface — the fleet-rollup
    # requirement stated in registry.rs module docs.
    for seed in range(6):
        a, b, c = _mk_registry(seed), _mk_registry(seed + 10), _mk_registry(seed + 20)
        left = a.snapshot()
        left.merge_from(b)
        left.merge_from(c)
        bc = b.snapshot()
        bc.merge_from(c)
        right = a.snapshot()
        right.merge_from(bc)
        assert left.counters == right.counters, f"seed {seed}"
        assert left.gauges == right.gauges, f"seed {seed}"
        assert {k: sorted(v) for k, v in left.histograms.items()} == {
            k: sorted(v) for k, v in right.histograms.items()
        }, f"seed {seed}"
        assert left.expose_prometheus("t") == right.expose_prometheus("t")
        # merge must leave the source untouched
        assert b.expose_prometheus("t") == _mk_registry(seed + 10).expose_prometheus("t")


def test_registry_merge_semantics_and_snapshot_independence():
    a = Registry()
    a.inc("n", (), 2.0)
    a.set_gauge("g", (), 10.0)
    a.observe("h", (), 1.0)
    snap = a.snapshot()
    b = Registry()
    b.inc("n", (), 3.0)
    b.set_gauge("g", (), 64.0)
    b.observe("h", (), 5.0)
    a.merge_from(b)
    assert a.counters[_key("n")] == 5.0  # counters sum
    assert a.gauges[_key("g")] == 64.0  # gauges LWW
    assert a.histograms[_key("h")] == [1.0, 5.0]  # samples concatenate
    # the earlier snapshot is a deep copy, not a view
    assert snap.counters[_key("n")] == 2.0
    assert snap.histograms[_key("h")] == [1.0]
    # label sets are order-insensitive
    c = Registry()
    c.inc("x", (("a", "1"), ("b", "2")), 1.0)
    c.inc("x", (("b", "2"), ("a", "1")), 1.0)
    assert c.counters[_key("x", (("a", "1"), ("b", "2")))] == 2.0


def test_prometheus_exposition_is_deterministic_and_shaped():
    r = _mk_registry(3)
    text = r.expose_prometheus("sparsespec")
    assert text == _mk_registry(3).expose_prometheus("sparsespec")
    assert "# TYPE sparsespec_requests_done counter" in text
    assert "# TYPE sparsespec_kv_used_tokens gauge" in text
    assert "# TYPE sparsespec_ttft_s summary" in text
    assert 'sparsespec_ttft_s{quantile="0.5"}' in text
    assert "sparsespec_ttft_s_count" in text
    # one TYPE line per series even with many labelled children
    assert text.count("# TYPE sparsespec_requests_done counter") == 1
