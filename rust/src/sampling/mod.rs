//! Token sampling + lossless speculative verification.
//!
//! Two verification modes, both lossless w.r.t. the target model:
//! * **greedy** — target and draft both argmax; a drafted token is accepted
//!   iff it equals the target argmax at its position (deterministic, used
//!   by the benchmark suite for reproducibility).
//! * **stochastic** — the Leviathan/Chen rejection-sampling rule: accept
//!   x with prob min(1, p(x)/q(x)), else resample from norm(max(p-q, 0));
//!   preserves the target distribution exactly (property-tested).

use crate::util::rng::Xoshiro256;

/// Softmax over logits at temperature `t` (t=0 ⇒ argmax one-hot).
pub fn softmax(logits: &[f32], t: f32) -> Vec<f32> {
    let mut p = Vec::new();
    softmax_into(logits, t, &mut p);
    p
}

/// [`softmax`] into a caller-owned buffer (cleared and refilled), so hot
/// loops reuse capacity instead of allocating a distribution per token.
pub fn softmax_into(logits: &[f32], t: f32, out: &mut Vec<f32>) {
    out.clear();
    if t <= 0.0 {
        out.resize(logits.len(), 0.0);
        out[argmax(logits)] = 1.0;
        return;
    }
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    out.extend(logits.iter().map(|&l| ((l - m) / t).exp()));
    let s: f32 = out.iter().sum();
    for x in out.iter_mut() {
        *x /= s;
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Sample from a categorical distribution.
pub fn sample_cat(p: &[f32], rng: &mut Xoshiro256) -> usize {
    let u = rng.unit() as f32;
    let mut acc = 0.0;
    for (i, &pi) in p.iter().enumerate() {
        acc += pi;
        if u < acc {
            return i;
        }
    }
    p.len() - 1
}

/// Sample a token from logits at temperature `t`.
pub fn sample_logits(logits: &[f32], t: f32, rng: &mut Xoshiro256) -> usize {
    if t <= 0.0 {
        argmax(logits)
    } else {
        sample_cat(&softmax(logits, t), rng)
    }
}

/// Outcome of verifying a drafted sequence.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct VerifyResult {
    /// Number of drafted tokens accepted (prefix length m ∈ [0, k]).
    pub accepted: usize,
    /// The bonus/correction token appended after the accepted prefix
    /// (target argmax / resample at the first rejected position, or the
    /// bonus continuation if everything was accepted).
    pub next_token: i32,
}

/// Greedy verification: `target_logits` holds k+1 rows of `vocab` logits
/// (row j = target distribution at draft position j); `draft` holds the k
/// drafted tokens.
pub fn verify_greedy(draft: &[i32], target_logits: &[f32], vocab: usize) -> VerifyResult {
    debug_assert!(target_logits.len() >= (draft.len() + 1) * vocab);
    let mut m = 0;
    for (j, &d) in draft.iter().enumerate() {
        let row = &target_logits[j * vocab..(j + 1) * vocab];
        if argmax(row) as i32 == d {
            m += 1;
        } else {
            break;
        }
    }
    let row = &target_logits[m * vocab..(m + 1) * vocab];
    VerifyResult { accepted: m, next_token: argmax(row) as i32 }
}

/// Stochastic (rejection-sampling) verification. `draft_probs` holds k rows
/// of the *draft* distribution each token was sampled from.
pub fn verify_stochastic(
    draft: &[i32],
    draft_probs: &[f32],
    target_logits: &[f32],
    vocab: usize,
    temp: f32,
    rng: &mut Xoshiro256,
) -> VerifyResult {
    debug_assert!(draft_probs.len() >= draft.len() * vocab);
    for (j, &d) in draft.iter().enumerate() {
        let p = softmax(&target_logits[j * vocab..(j + 1) * vocab], temp);
        let q = &draft_probs[j * vocab..(j + 1) * vocab];
        let (px, qx) = (p[d as usize], q[d as usize].max(1e-30));
        if (rng.unit() as f32) < (px / qx).min(1.0) {
            continue; // accepted
        }
        // Rejected: resample from norm(max(p - q, 0)).
        let mut res: Vec<f32> = p
            .iter()
            .zip(q.iter())
            .map(|(&pi, &qi)| (pi - qi).max(0.0))
            .collect();
        let s: f32 = res.iter().sum();
        let tok = if s <= 1e-12 {
            sample_cat(&p, rng)
        } else {
            for x in &mut res {
                *x /= s;
            }
            sample_cat(&res, rng)
        };
        return VerifyResult { accepted: j, next_token: tok as i32 };
    }
    // All accepted: bonus token from the (k+1)-th target row.
    let j = draft.len();
    let p = softmax(&target_logits[j * vocab..(j + 1) * vocab], temp);
    VerifyResult { accepted: j, next_token: sample_cat(&p, rng) as i32 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ptest;

    #[test]
    fn softmax_normalises() {
        let p = softmax(&[1.0, 2.0, 3.0], 1.0);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn softmax_temp_zero_is_argmax() {
        let p = softmax(&[0.1, 5.0, 2.0], 0.0);
        assert_eq!(p, vec![0.0, 1.0, 0.0]);
    }

    #[test]
    fn softmax_into_reuses_capacity() {
        let mut buf = Vec::new();
        softmax_into(&[1.0, 2.0, 3.0], 1.0, &mut buf);
        assert_eq!(buf, softmax(&[1.0, 2.0, 3.0], 1.0));
        let cap = buf.capacity();
        softmax_into(&[0.5, 0.25], 0.8, &mut buf);
        assert_eq!(buf.len(), 2);
        assert_eq!(buf.capacity(), cap, "refill must not reallocate");
        assert!((buf.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn greedy_accepts_matching_prefix() {
        let vocab = 4;
        // Target argmaxes: 2, 1, 3 (rows), draft proposes [2, 1].
        let mut logits = vec![0.0f32; 3 * vocab];
        logits[2] = 1.0;
        logits[vocab + 1] = 1.0;
        logits[2 * vocab + 3] = 1.0;
        let r = verify_greedy(&[2, 1], &logits, vocab);
        assert_eq!(r, VerifyResult { accepted: 2, next_token: 3 });
    }

    #[test]
    fn greedy_stops_at_first_mismatch() {
        let vocab = 4;
        let mut logits = vec![0.0f32; 3 * vocab];
        logits[2] = 1.0; // target wants 2
        logits[vocab + 1] = 1.0;
        let r = verify_greedy(&[0, 1], &logits, vocab);
        assert_eq!(r.accepted, 0);
        assert_eq!(r.next_token, 2); // correction = target argmax at row 0
    }

    ptest!(stochastic_accepts_identical_distributions, |g| {
        // Property: if draft dist == target dist, acceptance rate ~ 1.
        let vocab = 8;
        let mut rng = Xoshiro256::new(g.u64(0, u64::MAX / 2));
        let logits: Vec<f32> = (0..vocab).map(|_| g.f64(-2.0, 2.0) as f32).collect();
        let p = softmax(&logits, 1.0);
        let k = g.usize(1, 6);
        let mut target = Vec::new();
        let mut qs = Vec::new();
        let mut draft = Vec::new();
        for _ in 0..k {
            target.extend_from_slice(&logits);
            qs.extend_from_slice(&p);
            draft.push(sample_cat(&p, &mut rng) as i32);
        }
        target.extend_from_slice(&logits); // bonus row
        let r = verify_stochastic(&draft, &qs, &target, vocab, 1.0, &mut rng);
        assert_eq!(r.accepted, k, "identical dists must always accept");
    });

    ptest!(stochastic_result_in_vocab, |g| {
        let vocab = 16;
        let mut rng = Xoshiro256::new(g.u64(0, u64::MAX / 2));
        let k = g.usize(1, 8);
        let target: Vec<f32> = (0..(k + 1) * vocab).map(|_| g.f64(-3.0, 3.0) as f32).collect();
        let mut qs = Vec::new();
        let mut draft = Vec::new();
        for _ in 0..k {
            let ql: Vec<f32> = (0..vocab).map(|_| g.f64(-3.0, 3.0) as f32).collect();
            let q = softmax(&ql, 1.0);
            draft.push(sample_cat(&q, &mut rng) as i32);
            qs.extend(q);
        }
        let r = verify_stochastic(&draft, &qs, &target, vocab, 0.8, &mut rng);
        assert!(r.accepted <= k);
        assert!((0..vocab as i32).contains(&r.next_token));
    });

    /// Distribution-preservation test (the losslessness claim): the
    /// marginal of the *first* emitted token under speculative sampling
    /// must equal direct sampling from the target.
    #[test]
    fn stochastic_preserves_target_marginal() {
        let vocab = 4;
        let t_logits = vec![0.0f32, 1.0, 2.0, -1.0];
        let q_logits = vec![2.0f32, 0.0, 0.5, 0.0]; // deliberately different
        let p = softmax(&t_logits, 1.0);
        let q = softmax(&q_logits, 1.0);
        let mut rng = Xoshiro256::new(99);
        let n = 200_000;
        let mut counts = vec![0usize; vocab];
        for _ in 0..n {
            let d = sample_cat(&q, &mut rng) as i32;
            // one-step verify: target rows = [t_logits, t_logits]
            let mut target = t_logits.clone();
            target.extend_from_slice(&t_logits);
            let r = verify_stochastic(&[d], &q, &target, vocab, 1.0, &mut rng);
            let first = if r.accepted >= 1 { d } else { r.next_token };
            counts[first as usize] += 1;
        }
        for i in 0..vocab {
            let emp = counts[i] as f32 / n as f32;
            assert!(
                (emp - p[i]).abs() < 0.01,
                "token {i}: empirical {emp} vs target {}",
                p[i]
            );
        }
    }
}
