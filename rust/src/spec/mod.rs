//! Speculative decoding core: the pluggable [`Drafter`] API + registry,
//! the parse-layer drafter taxonomy, PillarAttn critical-token state, the
//! N-gram matcher, adaptive speculation length, and acceptance accounting.
//!
//! All drafters run inside the same engine and are verified by the same
//! dense verification artifact, so acceptance-rate comparisons (Fig. 12)
//! isolate exactly the drafting algorithm.  [`DrafterKind`] is the
//! serialisable CLI/parse surface; behaviour lives in [`drafter::Drafter`]
//! implementations resolved through the [`DrafterRegistry`].

pub mod adaptive;
pub mod drafter;
pub mod ngram;
pub mod pillar;

pub use adaptive::{AdaptiveDrafter, AdaptiveK, AdaptiveKCfg};
pub use drafter::{
    set_proposals, validate_drafter, DraftCtx, DraftHost, DraftMode, DraftPlan, Drafter,
    DrafterRegistry, VerifyFeedback,
};
pub use ngram::NGramIndex;
pub use pillar::{select_into, topk_indices, IndexPolicy, PillarState, SelectScratch};

/// Which draft model a request/engine names (paper system + every
/// baseline).  This is the *parse layer*: each kind resolves to a live
/// [`Drafter`] through the [`DrafterRegistry`], and out-of-crate policies
/// ride in through [`DrafterKind::Custom`] without extending this enum.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DrafterKind {
    /// No speculation: dense autoregressive decode (vLLM baseline).
    Vanilla,
    /// SparseSpec: PillarAttn — critical tokens re-identified from the
    /// verification score dump every stride (§4.1).
    Pillar { w: usize },
    /// MagicDec / StreamingLLM-style: attention sinks + sliding window.
    Window { w: usize },
    /// Oracle top-k (Fig. 3): critical tokens refreshed from exact scores
    /// after *every* step — upper bound for dynamic sparse selection.
    OracleTopK { w: usize },
    /// vLLM-NGram: longest-suffix n-gram proposals, no draft-model pass.
    NGram { n: usize },
    /// EAGLE-like trained draft head (Fig. 11).
    Eagle,
    /// TriForce-like hierarchy: NGram -> sliding-window model -> full.
    TriForce { w: usize },
    /// An out-of-crate drafter registered under `name` in the
    /// [`DrafterRegistry`] (see `spec::drafter` for a worked example).
    Custom { name: &'static str },
}

impl DrafterKind {
    pub fn parse(s: &str, w: usize, n: usize) -> Option<DrafterKind> {
        match s.to_ascii_lowercase().as_str() {
            "vanilla" | "vllm" | "baseline" => Some(DrafterKind::Vanilla),
            "pillar" | "sparsespec" | "ours" => Some(DrafterKind::Pillar { w }),
            "window" | "magicdec" | "streaming" => Some(DrafterKind::Window { w }),
            "oracle" | "oracletopk" => Some(DrafterKind::OracleTopK { w }),
            "ngram" => Some(DrafterKind::NGram { n }),
            "eagle" | "eagle3" => Some(DrafterKind::Eagle),
            "triforce" => Some(DrafterKind::TriForce { w }),
            _ => None,
        }
    }

    pub fn name(&self) -> String {
        match self {
            DrafterKind::Vanilla => "vanilla".into(),
            DrafterKind::Pillar { w } => format!("pillar_w{w}"),
            DrafterKind::Window { w } => format!("window_w{w}"),
            DrafterKind::OracleTopK { w } => format!("oracle_w{w}"),
            DrafterKind::NGram { n } => format!("ngram_n{n}"),
            DrafterKind::Eagle => "eagle".into(),
            DrafterKind::TriForce { w } => format!("triforce_w{w}"),
            DrafterKind::Custom { name } => (*name).into(),
        }
    }

    /// Parse the canonical [`DrafterKind::name`] form back (for trace
    /// files and reports): `"pillar_w64"`, `"ngram_n3"`, `"vanilla"`, …
    /// `Custom` kinds are not reconstructible from a string (their
    /// constructors live in a registry), so unknown names return `None`.
    pub fn parse_name(s: &str) -> Option<DrafterKind> {
        let (root, param) = match s.split_once('_') {
            Some((r, p)) => (r, Some(p)),
            None => (s, None),
        };
        let num = |pre: char| -> Option<usize> {
            param
                .and_then(|p| p.strip_prefix(pre))
                .and_then(|x| x.parse().ok())
        };
        match root {
            "vanilla" => Some(DrafterKind::Vanilla),
            "eagle" => Some(DrafterKind::Eagle),
            "pillar" => Some(DrafterKind::Pillar { w: num('w')? }),
            "window" => Some(DrafterKind::Window { w: num('w')? }),
            "oracle" => Some(DrafterKind::OracleTopK { w: num('w')? }),
            "ngram" => Some(DrafterKind::NGram { n: num('n')? }),
            "triforce" => Some(DrafterKind::TriForce { w: num('w')? }),
            _ => None,
        }
    }

    /// The [`DrafterRegistry`] key this kind resolves through.
    pub fn registry_key(&self) -> &'static str {
        match *self {
            DrafterKind::Vanilla => "vanilla",
            DrafterKind::Pillar { .. } => "pillar",
            DrafterKind::Window { .. } => "window",
            DrafterKind::OracleTopK { .. } => "oracle",
            DrafterKind::NGram { .. } => "ngram",
            DrafterKind::Eagle => "eagle",
            DrafterKind::TriForce { .. } => "triforce",
            DrafterKind::Custom { name } => name,
        }
    }

    /// Does this drafter run sparse-attention draft steps on the target
    /// model (self-speculation)?  Parse-layer heuristic only — the engine
    /// asks the resolved [`Drafter::mode`] instead.
    pub fn is_self_spec(&self) -> bool {
        matches!(
            self,
            DrafterKind::Pillar { .. }
                | DrafterKind::Window { .. }
                | DrafterKind::OracleTopK { .. }
        )
    }

    /// Sparse budget (W artifact variant), if applicable.
    pub fn budget(&self) -> Option<usize> {
        match self {
            DrafterKind::Pillar { w }
            | DrafterKind::Window { w }
            | DrafterKind::OracleTopK { w }
            | DrafterKind::TriForce { w } => Some(*w),
            _ => None,
        }
    }
}

/// Cumulative acceptance accounting (Fig. 12 left).
#[derive(Clone, Debug, Default)]
pub struct AcceptStats {
    /// Verification rounds.
    pub rounds: u64,
    /// Tokens drafted in total.
    pub drafted: u64,
    /// Drafted tokens accepted (bonus token NOT counted, per §5.3).
    pub accepted: u64,
    /// Histogram over accepted-prefix length m ∈ [0, k].
    pub accept_hist: Vec<u64>,
}

impl AcceptStats {
    pub fn new(k: usize) -> Self {
        AcceptStats { accept_hist: vec![0; k + 1], ..Default::default() }
    }

    pub fn record(&mut self, drafted: usize, accepted: usize) {
        self.rounds += 1;
        self.drafted += drafted as u64;
        self.accepted += accepted as u64;
        if accepted < self.accept_hist.len() {
            self.accept_hist[accepted] += 1;
        }
    }

    /// Average accepted tokens per round (the Fig. 12 bar height).
    pub fn mean_accepted(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.accepted as f64 / self.rounds as f64
        }
    }

    /// Per-token acceptance rate α.
    pub fn alpha(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        for (s, w, n) in [
            ("vanilla", 64, 3),
            ("pillar", 64, 3),
            ("magicdec", 128, 3),
            ("oracle", 32, 3),
            ("ngram", 64, 4),
            ("eagle", 64, 3),
            ("triforce", 64, 3),
        ] {
            let k = DrafterKind::parse(s, w, n).unwrap();
            assert!(DrafterKind::parse(k.name().split('_').next().unwrap(), w, n).is_some());
        }
        assert!(DrafterKind::parse("bogus", 0, 0).is_none());
    }

    #[test]
    fn name_parse_name_roundtrip() {
        for kind in [
            DrafterKind::Vanilla,
            DrafterKind::Pillar { w: 64 },
            DrafterKind::Window { w: 128 },
            DrafterKind::OracleTopK { w: 32 },
            DrafterKind::NGram { n: 3 },
            DrafterKind::Eagle,
            DrafterKind::TriForce { w: 64 },
        ] {
            assert_eq!(DrafterKind::parse_name(&kind.name()), Some(kind));
        }
        assert!(DrafterKind::parse_name("pillar_wNaN").is_none());
        assert!(DrafterKind::parse_name("pillar").is_none());
        assert!(DrafterKind::parse_name("bogus_w4").is_none());
        // custom names don't roundtrip through strings by design
        assert!(DrafterKind::parse_name("my-plugin").is_none());
        assert_eq!(DrafterKind::Custom { name: "my-plugin" }.name(), "my-plugin");
    }

    #[test]
    fn registry_keys_are_name_roots() {
        assert_eq!(DrafterKind::Pillar { w: 64 }.registry_key(), "pillar");
        assert_eq!(DrafterKind::NGram { n: 2 }.registry_key(), "ngram");
        assert_eq!(DrafterKind::Custom { name: "parrot" }.registry_key(), "parrot");
    }

    #[test]
    fn accept_stats_math() {
        let mut a = AcceptStats::new(8);
        a.record(8, 5);
        a.record(8, 8);
        a.record(8, 0);
        assert_eq!(a.rounds, 3);
        assert!((a.mean_accepted() - 13.0 / 3.0).abs() < 1e-9);
        assert!((a.alpha() - 13.0 / 24.0).abs() < 1e-9);
        assert_eq!(a.accept_hist[5], 1);
        assert_eq!(a.accept_hist[8], 1);
        assert_eq!(a.accept_hist[0], 1);
    }

    #[test]
    fn self_spec_classification() {
        assert!(DrafterKind::Pillar { w: 64 }.is_self_spec());
        assert!(DrafterKind::Window { w: 64 }.is_self_spec());
        assert!(!DrafterKind::NGram { n: 3 }.is_self_spec());
        assert!(!DrafterKind::Vanilla.is_self_spec());
        assert!(!DrafterKind::TriForce { w: 64 }.is_self_spec());
    }
}
