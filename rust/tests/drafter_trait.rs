//! Drafter-trait equivalence suite — the tentpole contract of the
//! pluggable-drafter redesign.
//!
//! * Every one of the seven `DrafterKind`s runs through the `Drafter`
//!   trait + `DrafterRegistry` and stays **lossless**: greedy speculative
//!   outputs are bit-identical to the vanilla chain (the seed pipeline's
//!   pinned invariant — `spec::pillar::reference` remains the selection
//!   oracle via the properties suite), so `RunReport.outputs` matches the
//!   pre-refactor engine on every drafter.
//! * Per-session drafter override dispatches identically to making the
//!   same drafter the engine default (same outputs, same iteration
//!   schedule).
//! * A mixed-drafter batch (pillar + ngram + vanilla concurrently)
//!   completes with per-drafter acceptance stats in `RunReport.accept_by`
//!   and per-drafter session metrics.
//! * Invalid overrides reject the session at submit without disturbing
//!   service; out-of-crate drafters register without touching the engine;
//!   `adaptive_k` stays lossless while bounding speculation.


use std::rc::Rc;

use sparsespec::engine::{Engine, EngineConfig, EngineDriver, EngineHandle, FinishReason};
use sparsespec::model::ModelConfig;
use sparsespec::runtime::Runtime;
use sparsespec::spec::{
    DraftCtx, DraftMode, DraftPlan, Drafter, DrafterKind, DrafterRegistry, IndexPolicy,
};
use sparsespec::workload::{Dataset, Request, WorkloadGen};

fn artifacts_dir() -> String {
    std::env::var("SPARSESPEC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

fn runtime() -> Rc<Runtime> {
    Rc::new(Runtime::load(&artifacts_dir()).expect("runtime loads"))
}

fn small_requests(rt: &Runtime, n: usize, cap: usize, seed: u64) -> Vec<Request> {
    let mut reqs =
        WorkloadGen::new(rt.cfg.grammar.clone(), rt.cfg.model.clone(), Dataset::Aime, seed)
            .offline_batch(n);
    for r in &mut reqs {
        r.max_new = r.max_new.min(cap);
    }
    reqs
}

/// All seven drafters dispatch through the trait and reproduce the
/// vanilla chain token-for-token under greedy decoding — the bit-identity
/// pin for `RunReport.outputs` across the enum-interpreter -> trait
/// refactor (the vanilla chain itself is pinned cross-language by
/// python/tests/test_sim_runtime_port.py).
#[test]
fn all_seven_drafters_run_through_the_trait_losslessly() {
    let rt = runtime();
    let reqs = small_requests(&rt, 4, 48, 99);
    let mut vanilla = Engine::new(rt.clone(), EngineConfig::new(DrafterKind::Vanilla)).unwrap();
    let base = vanilla.run(reqs.clone()).unwrap();
    assert_eq!(base.name, "vanilla");
    for drafter in [
        DrafterKind::Pillar { w: 64 },
        DrafterKind::Window { w: 64 },
        DrafterKind::OracleTopK { w: 64 },
        DrafterKind::NGram { n: 3 },
        DrafterKind::Eagle,
        DrafterKind::TriForce { w: 64 },
    ] {
        let mut eng = Engine::new(rt.clone(), EngineConfig::new(drafter).with_k(8)).unwrap();
        let r = eng.run(reqs.clone()).unwrap();
        assert_eq!(r.name, drafter.name(), "report name comes from the trait");
        assert_eq!(
            r.accept_by.len(),
            1,
            "single-drafter run has one accept_by entry"
        );
        assert!(r.accept_by.contains_key(&drafter.name()));
        for (id, out) in &base.outputs {
            assert_eq!(
                out,
                &r.outputs[id],
                "drafter {} diverged from vanilla on request {id}",
                drafter.name()
            );
        }
    }
}

/// Submitting every request with an explicit per-session override must
/// dispatch exactly like configuring that drafter as the engine default:
/// same outputs, same iteration schedule.
#[test]
fn per_session_override_matches_default_dispatch() {
    let rt = runtime();
    for kind in [
        DrafterKind::Window { w: 64 },
        DrafterKind::NGram { n: 3 },
        DrafterKind::Vanilla,
    ] {
        let reqs = small_requests(&rt, 5, 40, 7);
        // A: the drafter is the engine default (k follows the usual rule)
        let mut default_eng =
            Engine::new(rt.clone(), EngineConfig::new(kind).with_k(8)).unwrap();
        let ra = default_eng.run(reqs.clone()).unwrap();

        // B: a pillar-default engine, every session overriding to `kind`.
        // Vanilla-as-override keeps the engine k (8), so its rounds differ
        // from a vanilla-default engine (k = 0) — compare outputs only.
        let mut or = reqs.clone();
        for r in &mut or {
            r.drafter = Some(kind);
        }
        let mut override_eng = Engine::new(
            rt.clone(),
            EngineConfig::new(DrafterKind::Pillar { w: 64 })
                .with_k(8),
        )
        .unwrap();
        let rb = override_eng.run(or).unwrap();
        assert_eq!(ra.outputs, rb.outputs, "{kind:?} override diverged");
        if kind != DrafterKind::Vanilla {
            assert_eq!(ra.iterations, rb.iterations, "{kind:?} schedule diverged");
        }
        // the override engine accounted acceptance under the override name
        let by = rb.accept_by.get(&kind.name()).unwrap();
        assert!(by.rounds > 0, "{kind:?} recorded no rounds");
        // and the pillar default never served a round
        assert_eq!(rb.accept_by["pillar_w64"].rounds, 0);
    }
}

/// Pillar + ngram + vanilla sessions serve concurrently in ONE engine:
/// outputs stay lossless per session, and acceptance lands in per-drafter
/// buckets (RunReport::accept_by + per-drafter session metrics).
#[test]
fn mixed_drafter_sessions_share_one_engine() {
    let rt = runtime();
    let kinds = [
        None,
        Some(DrafterKind::NGram { n: 3 }),
        Some(DrafterKind::Vanilla),
    ];
    let mut reqs = small_requests(&rt, 6, 40, 31);
    for (i, r) in reqs.iter_mut().enumerate() {
        r.drafter = kinds[i % kinds.len()];
    }

    // greedy reference: same trace through a vanilla-only engine
    let mut plain = reqs.clone();
    for r in &mut plain {
        r.drafter = None;
    }
    let mut vanilla = Engine::new(rt.clone(), EngineConfig::new(DrafterKind::Vanilla)).unwrap();
    let base = vanilla.run(plain).unwrap();

    let cfg = EngineConfig::builder(DrafterKind::Pillar { w: 64 })
        .k(8)
        .allow_drafter(DrafterKind::NGram { n: 3 })
        .allow_drafter(DrafterKind::Vanilla)
        .build(&rt.cfg.model)
        .unwrap();
    let mut driver = EngineDriver::new(EngineHandle::new(rt.clone(), cfg).unwrap());
    let sessions: Vec<_> = reqs.iter().cloned().map(|r| driver.submit(r)).collect();
    driver.drive().unwrap();
    let report = driver.report();

    for (sess, req) in sessions.iter().zip(&reqs) {
        assert_eq!(sess.finish_reason(), Some(FinishReason::Completed));
        assert_eq!(
            sess.stats().drafter,
            req.drafter.unwrap_or(DrafterKind::Pillar { w: 64 }).name()
        );
    }
    assert_eq!(report.requests_done, reqs.len());
    assert_eq!(base.outputs, report.outputs, "mixed batch broke losslessness");
    // per-drafter acceptance: all three ran rounds; only the speculative
    // two drafted tokens
    for name in ["pillar_w64", "ngram_n3", "vanilla"] {
        let st = report.accept_by.get(name).unwrap_or_else(|| {
            panic!("accept_by missing {name}: {:?}", report.accept_by.keys())
        });
        assert!(st.rounds > 0, "{name} recorded no rounds");
    }
    assert!(report.accept_by["pillar_w64"].drafted > 0);
    assert_eq!(report.accept_by["vanilla"].drafted, 0);
    // per-drafter session metrics land next to the aggregates
    let m = driver.session_metrics();
    for name in ["pillar_w64", "ngram_n3", "vanilla"] {
        let by: &[(&str, &str)] = &[("drafter", name)];
        assert_eq!(
            m.counter("sessions_completed", by),
            2.0,
            "{name} session count"
        );
        assert!(
            m.histogram("accepted_per_round", by).is_some(),
            "{name} accepted_per_round breakdown missing"
        );
    }
}

/// An invalid per-session drafter rejects at submit — the session
/// finishes immediately with a readable reason, nothing queues, and the
/// rest of the batch is served bit-identically.
#[test]
fn invalid_override_rejects_without_disturbing_service() {
    let rt = runtime();
    let mut reqs = small_requests(&rt, 3, 32, 13);
    reqs[1].drafter = Some(DrafterKind::NGram { n: 0 }); // degenerate

    let mut reference = Engine::new(
        rt.clone(),
        EngineConfig::new(DrafterKind::Pillar { w: 64 }).with_k(8),
    )
    .unwrap();
    let mut good = reqs.clone();
    good.remove(1);
    let rr = reference.run(good).unwrap();

    let mut handle = EngineHandle::new(
        rt.clone(),
        EngineConfig::new(DrafterKind::Pillar { w: 64 }).with_k(8),
    )
    .unwrap();
    let sessions: Vec<_> = reqs.iter().cloned().map(|r| handle.submit(r)).collect();
    assert_eq!(sessions[1].finish_reason(), Some(FinishReason::Rejected));
    let why = sessions[1].reject_reason().expect("reject reason recorded");
    assert!(why.contains("n >= 1"), "unhelpful reject reason: {why}");
    assert_eq!(sessions[1].tokens_delivered(), 0);
    handle.drive().unwrap();
    let report = handle.report();
    assert_eq!(report.requests_rejected, 1);
    assert_eq!(report.requests_done, 2);
    assert_eq!(report.requests_cancelled, 0, "rejection is not cancellation");
    assert_eq!(rr.outputs, report.outputs);
    for (i, s) in sessions.iter().enumerate() {
        if i != 1 {
            assert_eq!(s.finish_reason(), Some(FinishReason::Completed));
        }
    }
}

/// The registry is the plugin point: an out-of-crate drafter registers a
/// constructor and serves sessions with zero engine changes — and dense
/// verification keeps even a terrible guesser lossless.
#[test]
fn custom_drafter_plugs_in_through_the_registry() {
    struct Parrot;
    impl Drafter for Parrot {
        fn kind(&self) -> DrafterKind {
            DrafterKind::Custom { name: "parrot" }
        }
        fn mode(&self) -> DraftMode {
            DraftMode::Proposal
        }
        fn index_policy(&self, m: &ModelConfig) -> IndexPolicy {
            IndexPolicy::pillar(m.draft_budget)
        }
        fn plan(&mut self, ctx: &DraftCtx) -> DraftPlan {
            // guess the pending token keeps repeating
            DraftPlan::proposals(vec![ctx.pending; ctx.k.min(ctx.remaining.max(1))])
        }
    }

    let rt = runtime();
    let reqs = small_requests(&rt, 3, 32, 5);
    let mut vanilla = Engine::new(rt.clone(), EngineConfig::new(DrafterKind::Vanilla)).unwrap();
    let base = vanilla.run(reqs.clone()).unwrap();

    let mut reg = DrafterRegistry::with_builtins();
    reg.register("parrot", |_, _| Ok(Box::new(Parrot)));
    let mut eng = Engine::with_registry(
        rt.clone(),
        EngineConfig::new(DrafterKind::Custom { name: "parrot" }).with_k(8),
        reg,
    )
    .unwrap();
    let r = eng.run(reqs).unwrap();
    assert_eq!(r.name, "parrot");
    assert_eq!(r.requests_done, 3);
    assert!(r.accept_by.contains_key("parrot"));
    assert_eq!(base.outputs, r.outputs, "custom drafter broke losslessness");

    // unknown custom names are rejected per-session, not a crash
    let mut handle =
        EngineHandle::new(rt.clone(), EngineConfig::new(DrafterKind::Vanilla)).unwrap();
    let mut req = small_requests(&rt, 1, 16, 1).remove(0);
    req.drafter = Some(DrafterKind::Custom { name: "not-registered" });
    let sess = handle.submit(req);
    assert_eq!(sess.finish_reason(), Some(FinishReason::Rejected));
    assert!(sess.reject_reason().unwrap().contains("not-registered"));
}

/// `adaptive_k` wraps the drafter in the AdaptiveK controller: greedy
/// outputs are invariant to speculation length (losslessness), while the
/// per-round draft length stays within [1, k].  (Controller convergence
/// itself is unit-tested in spec::adaptive; the narrowing-beats-static
/// scheduling claim is pinned numerically by
/// python/tests/test_drafter_dispatch_port.py.)
#[test]
fn adaptive_k_stays_lossless_and_bounded() {
    let rt = runtime();
    let reqs = small_requests(&rt, 4, 48, 21);
    let mut vanilla = Engine::new(rt.clone(), EngineConfig::new(DrafterKind::Vanilla)).unwrap();
    let base = vanilla.run(reqs.clone()).unwrap();

    for kind in [DrafterKind::Pillar { w: 64 }, DrafterKind::Window { w: 16 }] {
        let mut cfg = EngineConfig::new(kind).with_k(8);
        cfg.adaptive_k = true;
        let mut eng = Engine::new(rt.clone(), cfg).unwrap();
        let r = eng.run(reqs.clone()).unwrap();
        assert_eq!(r.name, format!("adaptive-{}", kind.name()));
        assert_eq!(r.requests_done, 4);
        assert_eq!(base.outputs, r.outputs, "{kind:?} adaptive broke losslessness");
        let st = &r.accept_by[&format!("adaptive-{}", kind.name())];
        assert!(st.rounds > 0);
        // never drafts beyond the ceiling in any round
        assert!(
            st.drafted <= st.rounds * 8,
            "adaptive exceeded k: {} drafted over {} rounds",
            st.drafted,
            st.rounds
        );
    }
}
