"""AOT entrypoint: train (once), lower every step function to HLO *text*,
write weights + config manifest.  Run via `make artifacts`.

Interchange format is HLO text, NOT `lowered.compile().serialize()` or the
HloModuleProto wire proto: jax >= 0.5 emits protos with 64-bit instruction
ids which xla_extension 0.5.1 (the version the published `xla` crate
builds against) rejects (`proto.id() <= INT_MAX`).  The HLO *text* parser
reassigns ids, so text round-trips cleanly.  See /opt/xla-example/README.

Artifacts written to --out-dir (default ../artifacts):
  config.json                  shapes + grammar + artifact manifest
  weights.bin                  target model flat f32 LE vector
  eagle.bin                    draft-head flat f32 LE vector
  train_log.csv                training curve (step, loss, acc)
  prefill.hlo.txt              prefill step
  draft_w{W}.hlo.txt           draft step per sparsity-budget variant
  verify_q{Q}.hlo.txt          verify step per speculative-k variant
  sparse_verify.hlo.txt        TriForce middle layer (Q=k+1, W=default)
  kv_load.hlo.txt              host->device KV onload
  eagle.hlo.txt                EAGLE-like draft head step
  draft_pallas.hlo.txt         compose-proof: draft lowered through the
  verify_pallas.hlo.txt        Pallas kernels (interpret mode)
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, train
from .config import MODEL, EAGLE, export_json


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    # return_tuple=False + the vendored crate's untuple_result patch give the
    # Rust side one PjRtBuffer per output (KV pools stay device-resident).
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_all(out_dir, log=print):
    cfg = MODEL
    S, T, P, L = cfg.slots, cfg.max_seq, cfg.prompt_pad, cfg.layers
    Hkv, D, V = cfg.kv_heads, cfg.head_dim, cfg.vocab
    NP = model.n_params(cfg)

    f32, i32 = jnp.float32, jnp.int32
    params = _spec((NP,))
    kv = _spec((L, S, T, Hkv, D))

    manifest = {}

    def emit(name, fn, *args, donate=()):
        t0 = time.time()
        # keep_unused: the PJRT calling convention must match the Python
        # signature exactly even when an argument is unused in one variant
        # (e.g. sparse_verify's q_valid) — otherwise the Rust side's
        # positional argument list goes out of sync.
        # donate: KV pools are threaded functionally through every step;
        # donating them adds input_output_alias to the HLO so XLA updates
        # the pools in place instead of copying 12.6 MB per step (§Perf:
        # -38% draft-step latency on this testbed).
        text = to_hlo_text(
            jax.jit(fn, keep_unused=True, donate_argnums=donate).lower(*args)
        )
        # jax emits may-alias; PJRT only honours it when the caller marks
        # the input buffer donated, which the xla crate's execute_b cannot.
        # must-alias makes XLA:CPU update the pools in place regardless
        # (§Perf: -7% draft-step latency; losslessness re-verified).
        text = text.replace("may-alias", "must-alias")
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "args": [list(a.shape) for a in args],
        }
        log(f"[aot] {name}: {len(text)//1024} KiB ({time.time()-t0:.1f}s)")

    # --- serving artifacts (ref kernel path; see DESIGN.md §2) -----------
    emit("prefill", model.make_prefill(cfg),
         params, kv, kv, _spec((S, P), i32), _spec((S,), i32), _spec((S,), i32),
         donate=(1, 2))

    for W in cfg.draft_w_variants:
        emit(f"draft_w{W}", model.make_draft(cfg),
             params, kv, kv, _spec((S,), i32), _spec((S,), i32),
             _spec((S, L, Hkv, W), i32), _spec((S,), i32), donate=(1, 2))

    for Q in cfg.verify_q_variants:
        emit(f"verify_q{Q}", model.make_verify(cfg),
             params, kv, kv, _spec((S, Q), i32), _spec((S,), i32),
             _spec((S,), i32), _spec((S,), i32), donate=(1, 2))

    Qd, Wd = cfg.spec_k + 1, cfg.draft_budget
    emit("sparse_verify", model.make_sparse_verify(cfg),
         params, kv, kv, _spec((S, Qd), i32), _spec((S,), i32),
         _spec((S,), i32), _spec((S, L, Hkv, Wd), i32), _spec((S,), i32),
         donate=(1, 2))

    emit("kv_load", model.make_kv_load(cfg),
         kv, kv, _spec((1,), i32), _spec((L, T, Hkv, D)), _spec((L, T, Hkv, D)),
         donate=(0, 1))

    emit("eagle", model.make_eagle(cfg, EAGLE),
         _spec((model.eagle_n_params(cfg, EAGLE),)), _spec((S, EAGLE.ctx), i32))

    # --- compose-proof artifacts (Pallas kernels, interpret mode) --------
    emit("draft_pallas", model.make_draft(cfg, impl="pallas"),
         params, kv, kv, _spec((S,), i32), _spec((S,), i32),
         _spec((S, L, Hkv, Wd), i32), _spec((S,), i32), donate=(1, 2))
    emit("verify_pallas", model.make_verify(cfg, impl="pallas"),
         params, kv, kv, _spec((S, Qd), i32), _spec((S,), i32),
         _spec((S,), i32), _spec((S,), i32), donate=(1, 2))

    return manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--skip-train", action="store_true",
                    help="random-init weights (fast; tests/dev only)")
    ap.add_argument("--force-train", action="store_true",
                    help="retrain even if weights.bin exists")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    t0 = time.time()
    wpath = os.path.join(args.out_dir, "weights.bin")
    epath = os.path.join(args.out_dir, "eagle.bin")
    curve = []
    if args.skip_train:
        params = model.init_params(jax.random.PRNGKey(0))
        eparams = model.eagle_init(jax.random.PRNGKey(1))
    elif (os.path.exists(wpath) and os.path.exists(epath)
          and not args.force_train):
        # Weights are deterministic given TrainConfig; reuse across re-lowers.
        print("[aot] reusing existing weights.bin / eagle.bin")
        params = jnp.asarray(np.fromfile(wpath, dtype=np.float32))
        eparams = jnp.asarray(np.fromfile(epath, dtype=np.float32))
    else:
        params, curve = train.train_model()
        eparams = train.train_eagle(params)

    np.asarray(params, dtype=np.float32).tofile(
        os.path.join(args.out_dir, "weights.bin"))
    np.asarray(eparams, dtype=np.float32).tofile(
        os.path.join(args.out_dir, "eagle.bin"))
    with open(os.path.join(args.out_dir, "train_log.csv"), "w") as f:
        f.write("step,loss,acc\n")
        for s, l, a in curve:
            f.write(f"{s},{l:.6f},{a:.4f}\n")

    manifest = lower_all(args.out_dir)

    doc = json.loads(export_json())
    doc["n_params"] = model.n_params(MODEL)
    doc["eagle_n_params"] = model.eagle_n_params(MODEL, EAGLE)
    doc["artifacts"] = manifest
    doc["trained"] = not args.skip_train
    with open(os.path.join(args.out_dir, "config.json"), "w") as f:
        f.write(json.dumps(doc, indent=2))
    print(f"[aot] done in {time.time()-t0:.0f}s -> {args.out_dir}")


if __name__ == "__main__":
    main()
