//! # SparseSpec — sparse self-speculative decoding for reasoning-model serving
//!
//! Reproduction of "Accelerating Large-Scale Reasoning Model Inference:
//! Self-Speculative Decoding with Sparse Attention" as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 1** (`python/compile/kernels/`): PillarAttn sparse attention,
//!   dense verification attention with zero-overhead score dumping, and the
//!   fused draft+verify kernel — Pallas, with pure-jnp oracles.
//! * **Layer 2** (`python/compile/model.py`): Qwen3-shaped decoder step
//!   functions, AOT-lowered once to HLO text (`make artifacts`).
//! * **Layer 3** (this crate): the serving coordinator — unified batch
//!   scheduler, delayed verification, dynamic two-tier KV-cache manager,
//!   PillarAttn critical-token state, all baselines, the benchmark harness.
//!
//! Python never runs on the request path: the Rust binary loads the HLO
//! artifacts through PJRT (`runtime`) and owns the entire serving loop.

pub mod bench;
pub mod engine;
pub mod kv_cache;
pub mod metrics;
pub mod model;
pub mod perfmodel;
pub mod runtime;
pub mod sampling;
pub mod scheduler;
pub mod spec;
pub mod util;
pub mod workload;
