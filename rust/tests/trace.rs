//! Observability integration tests: the tracing tentpole's contract.
//!
//! * Tracing is an **observer**: `Engine::run` outputs are bit-identical
//!   with tracing off vs on (the acceptance criterion), and a disabled
//!   tracer journals nothing.
//! * The Chrome/Perfetto export of a mixed-drafter run under KV pressure
//!   carries the full iteration anatomy: draft, verify, the
//!   delayed-verification overlap window, KV offloads, and the session
//!   lifecycle (submit → first token → finish).
//! * Simulated timestamps are monotone across the journal, sampling thins
//!   it, and the ring buffer drops oldest without losing count.
//! * The SLO section of `RunReport` is populated from the sim clock, and
//!   every report surface carries cancelled/rejected counts.

use std::rc::Rc;

use sparsespec::engine::{Engine, EngineConfig, EngineHandle, FinishReason};
use sparsespec::kv_cache::KvPolicy;
use sparsespec::runtime::Runtime;
use sparsespec::scheduler::Schedule;
use sparsespec::spec::DrafterKind;
use sparsespec::trace::{names, TraceConfig};
use sparsespec::workload::{Dataset, Request, WorkloadGen};

fn artifacts_dir() -> String {
    std::env::var("SPARSESPEC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

fn runtime() -> Rc<Runtime> {
    Rc::new(Runtime::load(&artifacts_dir()).expect("runtime loads"))
}

fn small_requests(rt: &Runtime, n: usize, cap: usize, seed: u64) -> Vec<Request> {
    let mut reqs =
        WorkloadGen::new(rt.cfg.grammar.clone(), rt.cfg.model.clone(), Dataset::Aime, seed)
            .offline_batch(n);
    for r in &mut reqs {
        r.max_new = r.max_new.min(cap);
    }
    reqs
}

/// A config that exercises every traced subsystem: mixed drafters,
/// delayed verification (overlap window), adaptive k, and a KV budget
/// tight enough to force offloads.
fn traced_cfg(rt: &Runtime, trace: TraceConfig) -> EngineConfig {
    let m = &rt.cfg.model;
    EngineConfig::builder(DrafterKind::Pillar { w: 64 })
        .k(8)
        .schedule(Schedule::Unified)
        .delayed_verify(true)
        .kv(KvPolicy::Dynamic, m.slots * m.max_seq / 8)
        .adaptive_k(true)
        .allow_drafter(DrafterKind::NGram { n: 3 })
        .allow_drafter(DrafterKind::Vanilla)
        .tracing(trace)
        .build(m)
        .expect("config validates")
}

fn mixed_requests(rt: &Runtime, n: usize, cap: usize, seed: u64) -> Vec<Request> {
    let mut reqs = small_requests(rt, n, cap, seed);
    for (i, r) in reqs.iter_mut().enumerate() {
        r.drafter = match i % 3 {
            1 => Some(DrafterKind::NGram { n: 3 }),
            2 => Some(DrafterKind::Vanilla),
            _ => None,
        };
    }
    reqs
}

#[test]
fn tracing_off_and_on_are_bit_identical() {
    let rt = runtime();
    let reqs = mixed_requests(&rt, 8, 60, 42);

    let mut off = Engine::new(rt.clone(), traced_cfg(&rt, TraceConfig::default())).unwrap();
    let r_off = off.run(reqs.clone()).unwrap();
    assert!(off.tracer().is_empty(), "disabled tracer must journal nothing");
    assert_eq!(off.tracer().dropped(), 0);

    let mut on = Engine::new(rt.clone(), traced_cfg(&rt, TraceConfig::on())).unwrap();
    let r_on = on.run(reqs).unwrap();
    assert!(!on.tracer().is_empty());

    assert_eq!(r_off.outputs, r_on.outputs, "tracing must not perturb generation");
    assert_eq!(r_off.tokens_generated, r_on.tokens_generated);
    assert_eq!(r_off.iterations, r_on.iterations);
}

#[test]
fn chrome_export_contains_the_full_iteration_anatomy() {
    let rt = runtime();
    let mut eng = Engine::new(rt.clone(), traced_cfg(&rt, TraceConfig::on())).unwrap();
    let report = eng.run(mixed_requests(&rt, 12, 80, 7)).unwrap();
    assert!(report.requests_done > 0);
    assert!(
        report.kv.offload_events > 0,
        "tight budget must force offloads (got {:?})",
        report.kv
    );

    let chrome = eng.export_trace_chrome();
    assert!(chrome.starts_with('{') && chrome.ends_with('}'));
    assert!(chrome.contains("\"traceEvents\""));
    for span in [
        names::ITERATION,
        names::ADMIT,
        names::DRAFT,
        names::PROPOSE,
        names::VERIFY,
        names::DELAYED_VERIFY_OVERLAP,
        names::KV_ADMIT,
        names::KV_OFFLOAD,
        names::BUCKET_ASSIGN,
        names::ADAPTIVE_K,
        names::SESSION_SUBMIT,
        names::SESSION_FIRST_TOKEN,
        names::SESSION_FINISH,
    ] {
        assert!(
            chrome.contains(&format!("\"{span}\"")),
            "chrome export missing `{span}`"
        );
    }
    // Counter series ride along.
    for counter in ["queue_depth", "kv_used_tokens", "live_sessions", "delayed_verify_depth"] {
        assert!(chrome.contains(counter), "missing counter `{counter}`");
    }
    // Finish reasons are labelled.
    assert!(chrome.contains("completed"));
}

#[test]
fn journal_sim_timestamps_are_monotone() {
    let rt = runtime();
    let mut eng = Engine::new(rt.clone(), traced_cfg(&rt, TraceConfig::on())).unwrap();
    eng.run(mixed_requests(&rt, 6, 40, 3)).unwrap();
    let jsonl = eng.export_trace_jsonl();
    let mut last = f64::NEG_INFINITY;
    let mut seen = 0usize;
    for line in jsonl.lines() {
        let Some(pos) = line.find("\"sim_us\":") else { continue };
        let rest = &line[pos + "\"sim_us\":".len()..];
        let end = rest
            .find(|c: char| c == ',' || c == '}')
            .expect("sim_us value terminates");
        let v: f64 = rest[..end].trim().parse().expect("sim_us parses");
        assert!(
            v >= last,
            "sim_us went backwards: {v} after {last} in line {line}"
        );
        last = v;
        seen += 1;
    }
    assert!(seen > 50, "expected a populated journal, saw {seen} events");
}

#[test]
fn sampling_thins_the_journal() {
    let rt = runtime();
    let reqs = small_requests(&rt, 6, 60, 11);

    let mut full = Engine::new(rt.clone(), traced_cfg(&rt, TraceConfig::on())).unwrap();
    full.run(reqs.clone()).unwrap();
    let mut thin =
        Engine::new(rt.clone(), traced_cfg(&rt, TraceConfig::on().with_sampling(4))).unwrap();
    thin.run(reqs).unwrap();

    assert!(
        thin.tracer().len() < full.tracer().len() / 2,
        "sample_every=4 should thin the journal ({} vs {})",
        thin.tracer().len(),
        full.tracer().len()
    );
    // Lifecycle instants are NOT sampled away.
    let chrome = thin.export_trace_chrome();
    assert!(chrome.contains(names::SESSION_SUBMIT));
    assert!(chrome.contains(names::SESSION_FINISH));
}

#[test]
fn ring_buffer_caps_and_counts_drops() {
    let rt = runtime();
    let mut eng = Engine::new(
        rt.clone(),
        traced_cfg(&rt, TraceConfig::on().with_capacity(64)),
    )
    .unwrap();
    eng.run(small_requests(&rt, 6, 60, 5)).unwrap();
    assert!(eng.tracer().len() <= 64);
    assert!(eng.tracer().dropped() > 0, "a long run must overflow capacity 64");
    // Export stays well-formed even with orphaned begin events dropped.
    let chrome = eng.export_trace_chrome();
    assert!(chrome.contains("\"traceEvents\""));
    assert!(chrome.contains("dropped_events"));
}

#[test]
fn slo_report_is_populated_from_the_sim_clock() {
    let rt = runtime();
    let mut eng = Engine::new(rt.clone(), traced_cfg(&rt, TraceConfig::default())).unwrap();
    let report = eng.run(mixed_requests(&rt, 12, 80, 21)).unwrap();

    let slo = &report.slo;
    assert_eq!(slo.completed, report.requests_done);
    assert_eq!(slo.ttft_target_s, 1.0, "default SLO target");
    assert_eq!(
        slo.ttft_sim_s.len(),
        report.requests_done,
        "one TTFT sample per completed request (none cancelled here)"
    );
    assert!(slo.itl_sim_s.len() > 0, "multi-token outputs must record ITL");
    assert!(slo.completed_within_ttft <= slo.completed);
    assert!(slo.goodput_rps >= 0.0 && slo.goodput_rps.is_finite());
    assert!(slo.kv_offloads > 0, "tight budget forces offloads");
    for (a, b) in [(25.0, 50.0), (50.0, 99.0)] {
        assert!(slo.ttft_sim_s.percentile(a) <= slo.ttft_sim_s.percentile(b));
    }

    // Markdown surface is deterministic and carries the SLO block.
    let md = report.to_markdown();
    assert!(md.contains("ttft_sim_s_p50"));
    assert!(md.contains("goodput_rps"));
    assert!(md.contains("requests_cancelled"));
    assert!(md.contains("requests_rejected"));
    assert_eq!(md, report.to_markdown(), "rendering is deterministic");
}

#[test]
fn every_report_surface_carries_cancel_and_reject_counts() {
    let rt = runtime();
    let mut handle = EngineHandle::new(rt.clone(), traced_cfg(&rt, TraceConfig::on())).unwrap();
    // One rejected (degenerate drafter parameters), the rest normal.
    let mut reqs = small_requests(&rt, 4, 30, 9);
    reqs[0].drafter = Some(DrafterKind::NGram { n: 0 });
    let handles: Vec<_> = reqs.into_iter().map(|r| handle.submit(r)).collect();
    assert_eq!(handles[0].finish_reason(), Some(FinishReason::Rejected));
    // Cancel one mid-queue before driving.
    handles[1].cancel();
    handle.drive().unwrap();
    let report = handle.report();

    assert_eq!(report.requests_rejected, 1);
    assert!(report.requests_cancelled >= 1);
    let summary = report.summary();
    assert!(summary.contains("canc="), "summary: {summary}");
    assert!(summary.contains("rej="), "summary: {summary}");
    let reg = report.registry();
    assert_eq!(reg.get("requests_rejected"), 1.0);
    assert!(reg.get("requests_cancelled") >= 1.0);
    let prom = reg.expose_prometheus("sparsespec");
    assert!(prom.contains("sparsespec_requests_rejected"));
    assert!(prom.contains("sparsespec_requests_cancelled"));
    // Session lifecycle instants made it to the journal, cancel included.
    let chrome = handle.tracer().export_chrome_string();
    assert!(chrome.contains(names::SESSION_FINISH));
    assert!(chrome.contains("cancelled"));
}
