//! Counting global allocator for the zero-allocation gates.
//!
//! [`CountingAlloc`] wraps [`System`] and counts every `alloc` /
//! `realloc` (frees and zero-size requests are not interesting: the gates
//! assert that the steady state *requests no new memory*, not that it
//! frees none).  It is installed as the `#[global_allocator]` by the
//! binaries that gate on allocation counts — `benches/bench_main.rs`
//! (the `engine_iteration` steady-state gate) and `tests/alloc_gate.rs`
//! (the same invariant as a plain test) — and deliberately **not** by the
//! library, so ordinary builds keep the untouched system allocator.
//!
//! Because only those binaries install it, gate code must distinguish
//! "zero allocations" from "nobody is counting": installation flips
//! [`INSTALLED`] at first use, and [`allocations`] returns `None` until
//! then.  Gates skip (with a note in the bench JSON) rather than
//! vacuously pass when the counter is absent.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

static COUNT: AtomicU64 = AtomicU64::new(0);
static INSTALLED: AtomicBool = AtomicBool::new(false);

/// `#[global_allocator]`-compatible counting wrapper over [`System`].
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        INSTALLED.store(true, Ordering::Relaxed);
        COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        COUNT.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

/// Total allocation count so far, or `None` when no [`CountingAlloc`] is
/// installed in this binary (gates should skip, not pass).
pub fn allocations() -> Option<u64> {
    if INSTALLED.load(Ordering::Relaxed) {
        Some(COUNT.load(Ordering::Relaxed))
    } else {
        None
    }
}

/// Allocations between two [`allocations`] snapshots; `None` if the
/// counter is absent.
pub fn allocations_since(base: Option<u64>) -> Option<u64> {
    match (allocations(), base) {
        (Some(now), Some(b)) => Some(now.saturating_sub(b)),
        _ => None,
    }
}
