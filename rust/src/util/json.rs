//! Minimal JSON parser + writer (no serde in this environment).
//!
//! Parses `artifacts/config.json` (written by `python/compile/aot.py`) and
//! serialises benchmark/metric reports.  Supports the full JSON grammar
//! except `\u` surrogate pairs beyond the BMP (not needed for our configs).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub at: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- accessors -----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path access: `doc.at(&["model", "hidden"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn keys(&self) -> Vec<&str> {
        match self {
            Json::Obj(m) => m.keys().map(|s| s.as_str()).collect(),
            _ => vec![],
        }
    }

    // ---- writer ----------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builder for report emission.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr<I: IntoIterator<Item = Json>>(v: I) -> Json {
    Json::Arr(v.into_iter().collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), at: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    let len = utf8_len(self.b[start]);
                    let end = (start + len).min(self.b.len());
                    s.push_str(
                        std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "hi\nthere", "c": true, "d": null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().as_str().unwrap(), "hi\nthere");
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn nested_path() {
        let v = Json::parse(r#"{"model": {"hidden": 128, "layers": 4}}"#).unwrap();
        assert_eq!(v.at(&["model", "hidden"]).unwrap().as_usize().unwrap(), 128);
        assert!(v.at(&["model", "missing"]).is_none());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""éA""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "éA");
    }

    #[test]
    fn parses_real_config_shape() {
        let src = r#"{"artifacts": {"prefill": {"file": "prefill.hlo.txt",
                      "args": [[656512], [4,12,512,2,32]]}}}"#;
        let v = Json::parse(src).unwrap();
        let args = v.at(&["artifacts", "prefill", "args"]).unwrap().as_arr().unwrap();
        assert_eq!(args[1].as_arr().unwrap()[2].as_usize().unwrap(), 512);
    }
}
