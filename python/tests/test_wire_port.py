"""Python twin of `rust/src/serving/wire.rs` (serving front-end PR).

Like ``test_fault_port.py`` and ``test_trace_port.py``, this twin
re-implements the wire codec bit-for-bit in Python and pins, by parsing
the Rust source directly:

* the frame-kind byte table (``K_SUBMIT`` .. ``K_PONG``),
* the protocol constants (``PROTOCOL_VERSION``, ``MAX_FRAME``,
  ``MAX_PROMPT``),
* the ``ErrorCode`` discriminants and metric labels,
* golden byte strings shared verbatim with ``rust/tests/wire.rs``,
* the rejection rules: truncated / oversized / trailing / unknown-kind /
  lying-prompt-count inputs all raise a typed error, never escape as a
  crash or a silently wrong frame.

If the wire layout drifts in Rust without a matching edit here, a test
below fails pointing at the divergence.
"""

from __future__ import annotations

import re
import struct
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[2]
WIRE_RS = REPO / "rust" / "src" / "serving" / "wire.rs"

PROTOCOL_VERSION = 1
MAX_FRAME = 1 << 20
MAX_PROMPT = 4096

KINDS = {
    "SUBMIT": 0x01,
    "CANCEL": 0x02,
    "CREDIT": 0x03,
    "SHUTDOWN": 0x04,
    "PING": 0x05,
    "HELLO": 0x10,
    "ACCEPTED": 0x11,
    "TOKEN": 0x12,
    "FINISHED": 0x13,
    "ERROR": 0x14,
    "PONG": 0x15,
}

# discriminant -> metric label, mirroring ErrorCode in wire.rs
ERROR_CODES = {
    1: "admission_reject",
    2: "kv_shed",
    3: "tenant_queue_full",
    4: "slow_reader",
    5: "drafter_rejected",
    6: "protocol",
    7: "draining",
    8: "engine_fault",
    9: "replica_down",
}

FINISH_REASONS = (0, 1, 2, 3)  # completed, cancelled, rejected, failed


class WireErr(Exception):
    """Typed decode failure (the twin of Rust's ``WireError``)."""


# ---------------------------------------------------------------------------
# Codec twin
# ---------------------------------------------------------------------------

def _s(text: str) -> bytes:
    b = text.encode("utf-8")
    return struct.pack("<H", len(b)) + b


def encode_body(frame: tuple) -> bytes:
    kind = frame[0]
    if kind == "submit":
        _, req_id, seed, max_new, tenant, drafter, prompt = frame
        out = bytes([KINDS["SUBMIT"]]) + struct.pack("<QQI", req_id, seed, max_new)
        out += _s(tenant) + _s(drafter) + struct.pack("<I", len(prompt))
        out += struct.pack(f"<{len(prompt)}i", *prompt) if prompt else b""
        return out
    if kind == "cancel":
        return bytes([KINDS["CANCEL"]]) + struct.pack("<Q", frame[1])
    if kind == "credit":
        return bytes([KINDS["CREDIT"]]) + struct.pack("<I", frame[1])
    if kind == "shutdown":
        return bytes([KINDS["SHUTDOWN"], 1 if frame[1] else 0])
    if kind == "ping":
        return bytes([KINDS["PING"]]) + struct.pack("<Q", frame[1])
    if kind == "hello":
        return bytes([KINDS["HELLO"], frame[1]]) + struct.pack("<I", frame[2])
    if kind == "accepted":
        # optional trailing replica id: absent encodes as absence
        out = bytes([KINDS["ACCEPTED"]]) + struct.pack("<QQ", frame[1], frame[2])
        if frame[3] is not None:
            out += struct.pack("<H", frame[3])
        return out
    if kind == "token":
        return bytes([KINDS["TOKEN"]]) + struct.pack("<QIi", frame[1], frame[2], frame[3])
    if kind == "finished":
        return bytes([KINDS["FINISHED"]]) + struct.pack("<QBI", frame[1], frame[2], frame[3])
    if kind == "error":
        return bytes([KINDS["ERROR"]]) + struct.pack("<QB", frame[1], frame[2]) + _s(frame[3])
    if kind == "pong":
        return bytes([KINDS["PONG"]]) + struct.pack("<Q", frame[1])
    raise AssertionError(f"unknown frame {kind}")


def encode(frame: tuple) -> bytes:
    body = encode_body(frame)
    return struct.pack("<I", len(body)) + body


class _Cur:
    def __init__(self, buf: bytes):
        self.buf, self.pos = buf, 0

    def take(self, n: int) -> bytes:
        if len(self.buf) - self.pos < n:
            raise WireErr("truncated")
        s = self.buf[self.pos : self.pos + n]
        self.pos += n
        return s

    def unpack(self, fmt: str):
        return struct.unpack(fmt, self.take(struct.calcsize(fmt)))

    def string(self) -> str:
        (n,) = self.unpack("<H")
        raw = self.take(n)
        try:
            return raw.decode("utf-8")
        except UnicodeDecodeError as e:
            raise WireErr("bad utf8") from e

    def rest(self) -> int:
        return len(self.buf) - self.pos


def decode_body(body: bytes) -> tuple:
    c = _Cur(body)
    (kind,) = c.unpack("<B")
    if kind == KINDS["SUBMIT"]:
        req_id, seed, max_new = c.unpack("<QQI")
        tenant, drafter = c.string(), c.string()
        (n,) = c.unpack("<I")
        if n > MAX_PROMPT:
            raise WireErr("bad value: prompt length")
        if c.rest() < n * 4:
            raise WireErr("truncated")
        prompt = list(c.unpack(f"<{n}i")) if n else []
        frame = ("submit", req_id, seed, max_new, tenant, drafter, prompt)
    elif kind == KINDS["CANCEL"]:
        frame = ("cancel", *c.unpack("<Q"))
    elif kind == KINDS["CREDIT"]:
        frame = ("credit", *c.unpack("<I"))
    elif kind == KINDS["SHUTDOWN"]:
        (mode,) = c.unpack("<B")
        if mode > 1:
            raise WireErr("bad value: shutdown mode")
        frame = ("shutdown", mode == 1)
    elif kind == KINDS["PING"]:
        frame = ("ping", *c.unpack("<Q"))
    elif kind == KINDS["HELLO"]:
        frame = ("hello", *c.unpack("<BI"))
    elif kind == KINDS["ACCEPTED"]:
        req_id, session = c.unpack("<QQ")
        replica = c.unpack("<H")[0] if c.rest() == 2 else None
        frame = ("accepted", req_id, session, replica)
    elif kind == KINDS["TOKEN"]:
        frame = ("token", *c.unpack("<QIi"))
    elif kind == KINDS["FINISHED"]:
        session, reason = c.unpack("<QB")
        if reason not in FINISH_REASONS:
            raise WireErr("bad value: finish reason")
        (tokens,) = c.unpack("<I")
        frame = ("finished", session, reason, tokens)
    elif kind == KINDS["ERROR"]:
        req_id, code = c.unpack("<QB")
        if code not in ERROR_CODES:
            raise WireErr("bad value: error code")
        frame = ("error", req_id, code, c.string())
    elif kind == KINDS["PONG"]:
        frame = ("pong", *c.unpack("<Q"))
    else:
        raise WireErr(f"unknown kind 0x{kind:02x}")
    if c.rest() != 0:
        raise WireErr(f"trailing: {c.rest()}")
    return frame


def decode(buf: bytes) -> tuple:
    if len(buf) < 4:
        raise WireErr("truncated")
    (n,) = struct.unpack("<I", buf[:4])
    if n == 0 or n > MAX_FRAME:
        raise WireErr(f"oversized: {n}")
    if len(buf) - 4 < n:
        raise WireErr("truncated")
    if len(buf) - 4 > n:
        raise WireErr("trailing")
    return decode_body(buf[4:])


# ---------------------------------------------------------------------------
# Source pinning
# ---------------------------------------------------------------------------

def test_kind_bytes_match_rust_source():
    src = WIRE_RS.read_text()
    for name, value in KINDS.items():
        m = re.search(rf"pub const K_{name}: u8 = (0x[0-9a-fA-F]+);", src)
        assert m, f"K_{name} missing from wire.rs"
        assert int(m.group(1), 16) == value, f"K_{name} drifted"
    assert re.search(rf"pub const PROTOCOL_VERSION: u8 = {PROTOCOL_VERSION};", src)
    assert re.search(r"pub const MAX_FRAME: usize = 1 << 20;", src)
    assert re.search(rf"pub const MAX_PROMPT: usize = {MAX_PROMPT};", src)


def test_error_codes_match_rust_source():
    src = WIRE_RS.read_text()
    for disc, label in ERROR_CODES.items():
        variant = "".join(p.capitalize() for p in label.split("_"))
        assert re.search(rf"{variant} = {disc},", src), f"{variant} discriminant drifted"
        assert re.search(rf'ErrorCode::{variant} => "{label}"', src), f"{variant} label drifted"
    # from_u8 covers exactly the table, nothing else
    assert f"{max(ERROR_CODES) + 1} =>" not in src


# ---------------------------------------------------------------------------
# Golden bytes (shared verbatim with rust/tests/wire.rs)
# ---------------------------------------------------------------------------

GOLDEN = [
    (
        ("submit", 1, 2, 3, "t", "d", [5, -1]),
        "270000000101000000000000000200000000000000030000000100740100640200000005000000ffffffff",
    ),
    (("hello", 1, 1024), "06000000100100040000"),
    (("error", 7, 2, "x"), "0d00000014070000000000000002010078"),
    (("token", 9, 4, -7), "1100000012090000000000000004000000f9ffffff"),
    (("accepted", 7, 3, None), "110000001107000000000000000300000000000000"),
    (("accepted", 7, 3, 1), "1300000011070000000000000003000000000000000100"),
]


def test_golden_bytes_pin_the_layout():
    for frame, hexstr in GOLDEN:
        assert encode(frame).hex() == hexstr, frame
        assert decode(bytes.fromhex(hexstr)) == frame


# ---------------------------------------------------------------------------
# Round-trip + rejection properties (seeded splitmix64, no hypothesis)
# ---------------------------------------------------------------------------

M64 = (1 << 64) - 1


def splitmix64(seed: int):
    state = seed & M64
    while True:
        state = (state + 0x9E3779B97F4A7C15) & M64
        z = state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
        yield z ^ (z >> 31)


def _rand_frame(rng) -> tuple:
    def u64():
        return next(rng)

    def u32():
        return next(rng) & 0xFFFFFFFF

    def i32():
        v = next(rng) & 0xFFFFFFFF
        return v - (1 << 32) if v >= 1 << 31 else v

    def s(maxlen):
        n = next(rng) % (maxlen + 1)
        return "".join(chr(ord("a") + next(rng) % 26) for _ in range(n))

    k = next(rng) % 11
    if k == 0:
        return ("submit", u64(), u64(), u32(), s(12), s(12),
                [i32() for _ in range(next(rng) % 64)])
    if k == 1:
        return ("cancel", u64())
    if k == 2:
        return ("credit", u32())
    if k == 3:
        return ("shutdown", next(rng) % 2 == 1)
    if k == 4:
        return ("ping", u64())
    if k == 5:
        return ("hello", next(rng) % 256, u32())
    if k == 6:
        replica = None if next(rng) % 2 == 0 else next(rng) & 0xFFFF
        return ("accepted", u64(), u64(), replica)
    if k == 7:
        return ("token", u64(), u32(), i32())
    if k == 8:
        return ("finished", u64(), next(rng) % 4, u32())
    if k == 9:
        return ("error", u64(), 1 + next(rng) % 9, s(40))
    return ("pong", u64())


def test_roundtrip_every_kind_fuzzed():
    rng = splitmix64(0xC0DEC)
    for _ in range(2000):
        f = _rand_frame(rng)
        assert decode(encode(f)) == f


def test_decode_is_canonical():
    rng = splitmix64(0xBEEF)
    for _ in range(2000):
        body = encode_body(_rand_frame(rng))
        assert encode_body(decode_body(body)) == body


def test_truncations_always_raise():
    rng = splitmix64(0x7A7A)
    for _ in range(200):
        f = _rand_frame(rng)
        body = encode_body(f)
        for cut in range(len(body)):
            # Sanctioned exception (mirrors rust/tests/wire.rs): slicing
            # off Accepted's optional replica id yields the equally
            # canonical replica-less form.
            if f[0] == "accepted" and f[3] is not None and cut == len(body) - 2:
                assert decode_body(body[:cut]) == ("accepted", f[1], f[2], None)
                continue
            with pytest.raises(WireErr):
                decode_body(body[:cut])


def test_garbage_never_escapes_typed_error():
    rng = splitmix64(0x6A6B)
    for _ in range(2000):
        blob = bytes(next(rng) & 0xFF for _ in range(next(rng) % 96))
        try:
            decode(blob)
        except WireErr:
            pass  # every failure is the typed one


def test_malformed_rejections():
    # unknown kind byte
    with pytest.raises(WireErr, match="unknown kind"):
        decode_body(bytes([0x7F]) + b"\0" * 8)
    # zero / oversized declared length
    with pytest.raises(WireErr, match="oversized"):
        decode(struct.pack("<I", 0))
    with pytest.raises(WireErr, match="oversized"):
        decode(struct.pack("<I", MAX_FRAME + 1) + b"\0")
    # trailing bytes after a valid payload
    with pytest.raises(WireErr, match="trailing"):
        decode_body(encode_body(("cancel", 5)) + b"\0")
    # lying prompt count on a short body (must not over-allocate)
    lying = encode_body(("submit", 1, 2, 3, "", "", []))[:-4] + struct.pack("<I", MAX_PROMPT)
    with pytest.raises(WireErr, match="truncated"):
        decode_body(lying)
    # absurd prompt count is a bad value even if the length field lies big
    huge = encode_body(("submit", 1, 2, 3, "", "", []))[:-4] + struct.pack("<I", MAX_PROMPT + 1)
    with pytest.raises(WireErr, match="prompt length"):
        decode_body(huge)
    # invalid finish reason / error code / shutdown mode bytes
    with pytest.raises(WireErr, match="finish reason"):
        decode_body(bytes([KINDS["FINISHED"]]) + struct.pack("<QBI", 1, 9, 0))
    with pytest.raises(WireErr, match="error code"):
        decode_body(bytes([KINDS["ERROR"]]) + struct.pack("<QB", 1, 99) + _s(""))
    with pytest.raises(WireErr, match="shutdown mode"):
        decode_body(bytes([KINDS["SHUTDOWN"], 2]))
    # non-utf8 string payload
    bad = bytes([KINDS["ERROR"]]) + struct.pack("<QB", 1, 1) + struct.pack("<H", 2) + b"\xff\xfe"
    with pytest.raises(WireErr, match="utf8"):
        decode_body(bad)


def expect_hello(frame: tuple):
    """Twin of ``wire::expect_hello``: the connection-opening handshake."""
    if frame[0] != "hello":
        raise WireErr("bad value: expected hello")
    if frame[1] != PROTOCOL_VERSION:
        raise WireErr("bad value: protocol version")
    return frame[2]


def test_hello_version_handshake_is_pinned():
    # positive path: the one supported version yields the credit window
    assert expect_hello(("hello", PROTOCOL_VERSION, 256)) == 256
    # negative path: any other version is a typed refusal, mirroring the
    # client/router hardening in wire.rs
    for v in (0, PROTOCOL_VERSION + 1, 255):
        with pytest.raises(WireErr, match="protocol version"):
            expect_hello(("hello", v, 256))
    with pytest.raises(WireErr, match="expected hello"):
        expect_hello(("pong", 1))
    # and the Rust side actually ships the guard
    src = WIRE_RS.read_text()
    assert "pub fn expect_hello" in src
    assert re.search(r"if \*version == PROTOCOL_VERSION => Ok\(\*window\)", src)
    for user in ("client.rs", "router.rs"):
        peer = WIRE_RS.parent / user
        assert "expect_hello" in peer.read_text(), f"{user} skips the version check"


def test_rust_twin_carries_the_same_goldens():
    """The golden hex strings must appear verbatim in rust/tests/wire.rs."""
    src = (REPO / "rust" / "tests" / "wire.rs").read_text()
    for _, hexstr in GOLDEN:
        assert hexstr in src, f"golden {hexstr[:16]}… missing from rust/tests/wire.rs"
