//! Deterministic PRNGs, re-implemented because no `rand` crate is available
//! in this environment (see DESIGN.md §1 "Crate availability").
//!
//! `SplitMix64` is bit-for-bit identical to `python/compile/data.py` —
//! golden tests on both sides pin the two implementations together so the
//! Rust workload generator samples from the model's training distribution.

/// SplitMix64: tiny, fast, language-portable. Used wherever cross-language
/// reproducibility matters (grammar traces, workload seeds).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Draw in [0, n). Modulo bias < 2^-32 for n << 2^64 (documented, fine
    /// for workload generation; matches the Python side exactly).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    /// Uniform in [0, 1) with 53 bits of mantissa.
    #[inline]
    pub fn unit(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }
}

/// xoshiro256++ — the general-purpose engine for sampling and property
/// tests (better equidistribution for long streams than SplitMix64).
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    pub fn new(seed: u64) -> Self {
        // Seed the state through SplitMix64, per Vigna's recommendation.
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let out = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        out
    }

    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's nearly-divisionless unbiased bounded draw.
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    #[inline]
    pub fn unit(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.unit().max(1e-300);
        let u2 = self.unit();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Log-normal with the given *arithmetic* mean and std.
    /// (Used to mimic the paper's Table 1 output-length distributions.)
    pub fn lognormal_mean_std(&mut self, mean: f64, std: f64) -> f64 {
        let cv2 = (std / mean).powi(2);
        let sigma2 = (1.0 + cv2).ln();
        let mu = mean.ln() - sigma2 / 2.0;
        (mu + sigma2.sqrt() * self.normal()).exp()
    }

    /// Poisson draw (Knuth for small lambda, normal approx for large).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.unit();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = lambda + lambda.sqrt() * self.normal();
            x.max(0.0).round() as u64
        }
    }

    /// Exponential inter-arrival time with the given rate.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.unit().max(1e-300).ln() / rate
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_golden() {
        // Golden values mirrored in python/tests/test_data.py.
        let mut r = SplitMix64::new(7);
        let vals: Vec<u64> = (0..4).map(|_| r.next_u64()).collect();
        let mut p = SplitMix64::new(7);
        assert_eq!(vals[0], p.next_u64());
        // Known first output of SplitMix64(0):
        let mut z = SplitMix64::new(0);
        assert_eq!(z.next_u64(), 0xE220_A839_7B1D_CDAF);
    }

    #[test]
    fn xoshiro_bounds() {
        let mut r = Xoshiro256::new(42);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
            let u = r.unit();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn lognormal_moments() {
        let mut r = Xoshiro256::new(1);
        let (mean, std) = (200.0, 80.0);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.lognormal_mean_std(mean, std)).collect();
        let m = xs.iter().sum::<f64>() / n as f64;
        let v = xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / n as f64;
        assert!((m - mean).abs() < mean * 0.05, "mean {m}");
        assert!((v.sqrt() - std).abs() < std * 0.15, "std {}", v.sqrt());
    }

    #[test]
    fn poisson_mean() {
        let mut r = Xoshiro256::new(3);
        let lambda = 6.5;
        let n = 20_000;
        let s: u64 = (0..n).map(|_| r.poisson(lambda)).sum();
        let m = s as f64 / n as f64;
        assert!((m - lambda).abs() < 0.2, "mean {m}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
