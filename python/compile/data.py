"""Synthetic "reasoning trace" corpus generator (pointer-chasing grammar).

Build-time only.  The Rust workload generator re-implements the same
grammar (see rust/src/workload/grammar.rs) from the constants exported in
artifacts/config.json — a golden-trace pytest (test_data.py) and a Rust
unit test pin both implementations to the same token stream for the same
seed, so prompts generated in Rust come from the model's training
distribution.

RNG: SplitMix64, chosen because it is trivially portable between Python
and Rust (the Rust side uses the identical constants).
"""

from .config import GRAMMAR, GrammarConfig

MASK64 = (1 << 64) - 1


class SplitMix64:
    """Deterministic, language-portable PRNG (same impl in rust/util/rng.rs)."""

    def __init__(self, seed: int):
        self.state = seed & MASK64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return z ^ (z >> 31)

    def below(self, n: int) -> int:
        """Unbiased-enough modulo draw (documented bias < 2^-32 for n << 2^64)."""
        return self.next_u64() % n

    def unit(self) -> float:
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))


class TraceGen:
    """Stateful generator of one reasoning trace.

    A trace = BOS, n_defs definition blocks (``DEF slot value SEP``) and then
    an unbounded body of blocks, each either
      * a query block  ``QRY slot EQ value[slot] SEP``  (long-range lookup), or
      * a redefinition ``DEF slot value' SEP``          (context *dynamics*), or
      * a filler run   ``f, next(f), next(next(f)), ...`` (locally predictable).
    """

    def __init__(self, seed: int, g: GrammarConfig = GRAMMAR):
        self.g = g
        self.rng = SplitMix64(seed)
        self.slots = {}
        self.focus = None
        self.buf = []
        self._emit_header()

    def _slot_tok(self, i: int) -> int:
        return self.g.slot_base + i

    def _val_tok(self, i: int) -> int:
        return self.g.value_base + i

    def _emit_header(self):
        g = self.g
        self.buf.append(g.bos)
        for i in range(g.n_defs):
            s = self.rng.below(g.n_slots)
            v = self.rng.below(g.n_values)
            self.slots[s] = v
            self.buf += [g.def_tok, self._slot_tok(s), self._val_tok(v), g.sep]

    def _pick_focus(self):
        keys = sorted(self.slots.keys())
        self.focus = keys[self.rng.below(len(keys))]

    def _emit_block(self):
        g = self.g
        r = self.rng.unit()
        if r < g.query_prob and self.slots:
            if self.focus is None or self.focus not in self.slots:
                self._pick_focus()
            # queries dwell on the focus slot (temporal locality of the
            # critical definition), occasionally probing another slot
            if self.rng.unit() < g.focus_query_prob:
                s = self.focus
            else:
                keys = sorted(self.slots.keys())
                s = keys[self.rng.below(len(keys))]
            self.buf += [g.qry, self._slot_tok(s), g.eq,
                         self._val_tok(self.slots[s]), g.sep]
            if self.rng.unit() < g.focus_switch_prob:
                self._pick_focus()
        elif r < g.query_prob + g.redefine_prob:
            s = self.rng.below(g.n_slots)
            v = self.rng.below(g.n_values)
            self.slots[s] = v
            self.buf += [g.def_tok, self._slot_tok(s), self._val_tok(v), g.sep]
        else:
            m = self.rng.below(g.n_modes)
            f = g.filler_base + self.rng.below(g.n_filler)
            run = 3 + self.rng.below(6)
            self.buf.append(g.mode_base + m)
            for j in range(run):
                self.buf.append(f)
                f = g.filler_next(f, m, j)

    def take(self, n: int):
        """Return the next n tokens of the trace."""
        while len(self.buf) < n:
            self._emit_block()
        out, self.buf = self.buf[:n], self.buf[n:]
        return out


def training_batch(rng_seed: int, batch: int, seq: int):
    """[batch, seq+1] token matrix; model trains on next-token prediction."""
    import numpy as np

    out = np.zeros((batch, seq + 1), dtype=np.int32)
    for b in range(batch):
        gen = TraceGen(seed=(rng_seed * 0x5851F42D + b * 0x14057B7E) & MASK64)
        out[b] = np.array(gen.take(seq + 1), dtype=np.int32)
    return out


def prompt(seed: int, g: GrammarConfig = GRAMMAR):
    """A serving prompt: the definition header plus a couple of body blocks.

    Mirrors rust/src/workload/grammar.rs::prompt — pinned by golden tests.
    """
    gen = TraceGen(seed, g)
    # header is 1 + 4*n_defs tokens; add a couple of blocks of context
    n = 1 + 4 * g.n_defs
    gen.take(0)
    while len(gen.buf) < n + 8:
        gen._emit_block()
    return gen.take(min(len(gen.buf), 32))


if __name__ == "__main__":
    toks = TraceGen(7).take(64)
    print(toks)
