//! Per-slot request state.

use crate::spec::{DraftMode, NGramIndex, PillarState};
use crate::workload::Request;

/// Where a slot is inside its speculation round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Running sparse draft steps (self-spec) or collecting proposals.
    Drafting,
    /// Draft buffer full; waiting for the verification iteration.
    ReadyVerify,
    /// Verification launched; result consumed next iteration (§4.3).
    AwaitVerify,
}

impl Phase {
    /// Stable lowercase label for trace args and reports.
    pub fn label(&self) -> &'static str {
        match self {
            Phase::Drafting => "drafting",
            Phase::ReadyVerify => "ready_verify",
            Phase::AwaitVerify => "await_verify",
        }
    }
}

/// One resident request.
pub struct Slot {
    pub req: Request,
    /// KV frontier: positions [0, len) hold valid keys/values.
    pub len: usize,
    /// Accepted generated tokens so far (== output.len()).
    pub gen_count: usize,
    /// Next token to feed (sampled, KV not yet written).
    pub pending: i32,
    /// Anchor = round-start pending token (first token fed this round).
    pub anchor: i32,
    /// Anchor position == KV frontier at round start.
    pub round_start_len: usize,
    /// Drafted (provisional) tokens this round, in order.
    pub drafts: Vec<i32>,
    /// Draft distributions (k rows × vocab) for stochastic verification.
    pub draft_probs: Vec<f32>,
    /// How many drafts to take this round (shortened first round aligns
    /// the slot with its bucket — Fig. 8).
    pub draft_target: usize,
    pub phase: Phase,
    pub bucket: usize,
    /// Index into the engine's resolved drafter table (per-session
    /// drafter selection: every slot carries its own policy).
    pub drafter: usize,
    /// Cached `Drafter::mode()` of this slot's drafter (hot-loop gate).
    pub mode: DraftMode,
    /// Cached sparse budget W — selects the `draft_w{W}` artifact group
    /// this slot drafts in.
    pub draft_w: usize,
    /// Cached `Drafter::wants_dump_refresh()` — whether verification's
    /// score dump refreshes this slot's critical-token state.
    pub refresh_dump: bool,
    /// PillarAttn / window critical-token state.
    pub pillar: PillarState,
    /// N-gram history index (NGram + TriForce drafters).
    pub ngram: NGramIndex,
    /// Accepted output tokens.
    pub output: Vec<i32>,
    /// Wallclock admission time (for latency accounting).
    pub admitted_at: std::time::Instant,
    /// Simulated-clock admission time.
    pub sim_admitted_at: f64,
}

impl Slot {
    pub fn remaining(&self) -> usize {
        self.req.max_new.saturating_sub(self.gen_count)
    }

    pub fn done(&self) -> bool {
        self.gen_count >= self.req.max_new
    }

    /// The token sequence so far (prompt + accepted output).
    pub fn full_context(&self) -> Vec<i32> {
        let mut v = self.req.prompt.clone();
        v.extend_from_slice(&self.output);
        v
    }

    /// Start a fresh speculation round.
    pub fn begin_round(&mut self, draft_target: usize) {
        self.anchor = self.pending;
        self.round_start_len = self.len;
        self.drafts.clear();
        self.draft_probs.clear();
        self.draft_target = draft_target;
        self.phase = if draft_target == 0 {
            Phase::ReadyVerify
        } else {
            Phase::Drafting
        };
    }
}
