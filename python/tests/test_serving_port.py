"""Python twin of the serving front-end's policy kernels
(`rust/src/serving/server.rs`).

Two pieces of the server are pure decision logic, re-implemented here
bit-for-bit and pinned against the Rust source:

* ``WrrQueues`` — bounded per-tenant FIFOs drained by deficit-weighted
  round-robin.  Single-pass rounds: a non-empty queue earns its weight in
  deficit once per round and releases one item per whole unit; empty
  queues forfeit deficit (no banking); the first global ``can_admit``
  refusal ends the whole round.
* The credit-gated outbound queue — tokens need both queue headroom and
  reader-granted credit, control frames bypass credit (but a closed
  queue refuses everything).

The scenarios mirror the Rust unit tests in ``server.rs`` with identical
expected values, so the two implementations cannot drift silently; the
config-default pins parse the Rust source directly.
"""

from __future__ import annotations

import re
from collections import deque
from pathlib import Path

REPO = Path(__file__).resolve().parents[2]
SERVER_RS = REPO / "rust" / "src" / "serving" / "server.rs"


class WrrQueues:
    """Twin of `WrrQueues<T>`: name-ordered tenants, deficit round-robin."""

    def __init__(self, weights: dict[str, float], cap: int):
        self.weights = dict(weights)
        self.cap = cap
        self.tenants: dict[str, dict] = {}  # name -> {weight, deficit, q}

    def _weight_of(self, tenant: str) -> float:
        w = self.weights.get(tenant, 1.0)
        return w if (w > 0.0 and w == w and w != float("inf")) else 1.0

    def push(self, tenant: str, item):
        tq = self.tenants.setdefault(
            tenant, {"weight": self._weight_of(tenant), "deficit": 0.0, "q": deque()}
        )
        if len(tq["q"]) >= self.cap:
            return False  # Rust: Err(item)
        tq["q"].append(item)
        return True

    def admit_round(self, maximum: int, can_admit) -> list[tuple[str, object]]:
        out: list[tuple[str, object]] = []
        for name in sorted(self.tenants):  # BTreeMap iteration order
            tq = self.tenants[name]
            if not tq["q"]:
                tq["deficit"] = 0.0  # no banking while idle
                continue
            tq["deficit"] += tq["weight"]
            while tq["deficit"] >= 1.0 and len(out) < maximum:
                if not tq["q"]:
                    break
                if not can_admit(tq["q"][0]):
                    return out  # global resource exhausted: end the round
                tq["deficit"] -= 1.0
                out.append((name, tq["q"].popleft()))
            if len(out) >= maximum:
                break
        return out

    def total_len(self) -> int:
        return sum(len(t["q"]) for t in self.tenants.values())


class ConnOut:
    """Twin of the credit/cap gate in `ConnOut::try_token` / `push_ctrl`."""

    def __init__(self, cap: int, window: int):
        self.cap = cap
        self.credit = window
        self.q: deque = deque()
        self.closed = False

    def try_token(self, frame) -> bool:
        if self.closed or self.credit == 0 or len(self.q) >= self.cap:
            return False
        self.credit -= 1
        self.q.append(frame)
        return True

    def push_ctrl(self, frame) -> bool:
        if self.closed:
            return False
        self.q.append(frame)
        return True

    def add_credit(self, n: int):
        self.credit = min(self.credit + n, (1 << 32) - 1)


# ---------------------------------------------------------------------------
# Scenario twins — identical numbers to the server.rs unit tests.
# ---------------------------------------------------------------------------

def test_wrr_respects_weights_under_saturation():
    qs = WrrQueues({"a": 2.0, "b": 1.0}, 1000)
    for i in range(300):
        assert qs.push("a", i)
        assert qs.push("b", 1000 + i)
    got = {"a": 0, "b": 0}
    for _ in range(60):
        for tenant, _ in qs.admit_round(3, lambda _i: True):
            got[tenant] += 1
    assert got["a"] + got["b"] == 180
    # saturated 2:1 weights admit exactly 2:1 per round here (deficit of
    # 'b' banks only while its queue is non-empty and it gets its turn)
    assert abs(got["a"] / got["b"] - 2.0) < 0.2


def test_wrr_is_fifo_within_a_tenant_and_bounded():
    qs = WrrQueues({}, 3)
    for i in (1, 2, 3):
        assert qs.push("t", i)
    assert not qs.push("t", 4), "cap is enforced"
    admitted = []
    for _ in range(3):  # weight 1 => one item per round
        admitted += [v for _, v in qs.admit_round(10, lambda _i: True)]
    assert admitted == [1, 2, 3], "FIFO per tenant"
    assert qs.total_len() == 0


def test_wrr_global_refusal_ends_the_round():
    qs = WrrQueues({"a": 3.0}, 100)
    for i in range(10):
        assert qs.push("a", i)
        assert qs.push("b", 100 + i)
    allowance = {"n": 3}

    def can_admit(_item):
        if allowance["n"] > 0:
            allowance["n"] -= 1
            return True
        return False

    admitted = qs.admit_round(1 << 60, can_admit)
    assert all(t == "a" for t, _ in admitted)
    assert len(admitted) == 3, "refusal stops everything, nothing is lost"
    assert qs.total_len() == 17


def test_wrr_idle_tenants_do_not_bank_deficit():
    qs = WrrQueues({"a": 4.0}, 100)
    for _ in range(10):
        assert qs.admit_round(10, lambda _i: True) == []
    for i in range(10):
        assert qs.push("a", i)
        assert qs.push("b", 100 + i)
    first = [t for t, _ in qs.admit_round(1 << 60, lambda _i: True)]
    assert first.count("a") <= 4, "one round grants at most the weight"


def test_wrr_admission_order_is_name_then_fifo():
    # one full round: 'a' (weight 2) releases two, then 'b' one — in
    # BTreeMap name order, FIFO within each tenant
    qs = WrrQueues({"a": 2.0, "b": 1.0}, 100)
    for i in range(5):
        qs.push("b", f"b{i}")
        qs.push("a", f"a{i}")
    assert qs.admit_round(1 << 60, lambda _i: True) == [
        ("a", "a0"),
        ("a", "a1"),
        ("b", "b0"),
    ]


def test_conn_out_credit_gating_and_ctrl_bypass():
    out = ConnOut(cap=4, window=2)
    assert out.try_token("t0")
    assert out.try_token("t1")
    assert not out.try_token("t2"), "credit exhausted"
    assert out.push_ctrl("pong"), "control bypasses credit"
    out.add_credit(1)
    assert out.try_token("t2")
    assert not out.try_token("t3"), "queue cap binds even with credit"
    out.closed = True
    assert not out.push_ctrl("pong2"), "closed refuses everything"


# ---------------------------------------------------------------------------
# Source pins: config defaults and policy constants in server.rs.
# ---------------------------------------------------------------------------

def test_server_config_defaults_pinned():
    src = SERVER_RS.read_text()
    new_block = src.split("impl ServerConfig")[1].split("}")[0:6]
    blob = "}".join(new_block)
    for field, value in [
        ("send_window", "1024"),
        ("send_queue_cap", "1024 + 64"),
        ("stall_ticks", "2000"),
        ("kv_shed_watermark", "0.85"),
        ("tenant_queue_cap", "64"),
        ("max_inflight", "0"),
        ("metrics_publish_every", "16"),
    ]:
        assert re.search(rf"{field}: {re.escape(value)},", blob), f"{field} default drifted"


def test_wrr_semantics_pinned_in_source():
    src = SERVER_RS.read_text()
    # no banking while idle
    assert "tq.deficit = 0.0; // no banking while idle" in src
    # a global refusal returns early, ending the whole round
    assert "return out; // global resource exhausted: end the round" in src
    # the engine-side admission projects prompt_pad + k + 2 per unstarted
    # session (the server's worst-case KV estimate)
    assert re.search(r"let est = self\.prompt_pad \+ self\.cfg\.engine\.k \+ 2;", src)
