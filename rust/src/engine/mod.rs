//! The SparseSpec serving engine (Layer 3).
//!
//! One `Engine` drives one drafter configuration over a request trace:
//! admission → (draft* → verify) rounds → acceptance/rollback → retire,
//! with the unified batch scheduler (§4.2), delayed verification (§4.3)
//! and the dynamic KV manager (§4.4) wired in.  Every baseline of the
//! paper's evaluation runs through this same engine with a different
//! `DrafterKind`, so comparisons isolate the drafting/scheduling policy.
//!
//! Timing is accounted twice (DESIGN.md §1):
//! * **wallclock** — real time on this CPU testbed (PJRT executes the AOT
//!   artifacts; shapes are static, so inactive batch rows cost as much as
//!   active ones), and
//! * **simulated** — the calibrated H100 `DeviceModel` applied to the
//!   engine's *real* per-iteration schedule (rows drafted/verified, KV
//!   bytes actually touched).  Scheduling experiments (Figs. 13/14) read
//!   the simulated clock; acceptance and correctness are identical.

mod core;
mod slot;

pub use self::core::Engine;
pub use slot::{Phase, Slot};

use crate::kv_cache::KvPolicy;
use crate::scheduler::Schedule;
use crate::spec::{AcceptStats, DrafterKind};

#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub drafter: DrafterKind,
    /// Draft length k (verification uses the verify_q{k+1} artifact).
    pub k: usize,
    pub schedule: Schedule,
    /// Overlap verification CPU work with the next iteration (§4.3).
    pub delayed_verify: bool,
    pub kv_policy: KvPolicy,
    /// Device KV capacity in tokens (models HBM; < slots×max_seq so the
    /// §4.4 policies are exercised).
    pub kv_budget: usize,
    /// 0.0 => greedy (deterministic); paper uses 0.65.
    pub temperature: f32,
    pub seed: u64,
    /// Safety valve for tests/benches.
    pub max_iterations: u64,
    pub verbose: bool,
    /// Simulated-clock calibration (None => paper scale; see perfmodel).
    pub sim_scale: Option<crate::perfmodel::SimScale>,
}

impl EngineConfig {
    pub fn new(drafter: DrafterKind) -> Self {
        EngineConfig {
            drafter,
            k: 8,
            schedule: Schedule::Lockstep,
            delayed_verify: false,
            kv_policy: KvPolicy::Dynamic,
            kv_budget: usize::MAX / 2, // effectively unbounded by default
            temperature: 0.0,
            seed: 7,
            max_iterations: 1_000_000,
            verbose: false,
            sim_scale: None,
        }
    }

    pub fn with_k(mut self, k: usize) -> Self {
        self.k = k;
        self
    }

    pub fn with_schedule(mut self, s: Schedule, delayed: bool) -> Self {
        self.schedule = s;
        self.delayed_verify = delayed;
        self
    }

    pub fn with_kv(mut self, policy: KvPolicy, budget: usize) -> Self {
        self.kv_policy = policy;
        self.kv_budget = budget;
        self
    }
}

/// Everything a run produces (one row of the paper's figures).
#[derive(Clone, Debug)]
pub struct RunReport {
    pub name: String,
    pub iterations: u64,
    pub wall_s: f64,
    /// Simulated H100 time of the same schedule.
    pub sim_s: f64,
    pub sim_cpu_s: f64,
    pub requests_done: usize,
    pub tokens_generated: u64,
    pub accept: AcceptStats,
    pub kv: crate::kv_cache::KvStats,
    pub offload: crate::kv_cache::OffloadStats,
    pub trace: crate::scheduler::ScheduleTrace,
    pub step_stats: crate::runtime::StepStats,
    /// Mean device-KV utilisation over the run (Fig. 5).
    pub mean_kv_util: f64,
    /// Outputs per request id (for losslessness checks).
    pub outputs: std::collections::BTreeMap<u64, Vec<i32>>,
    pub request_latency_s: crate::metrics::Histogram,
}

impl RunReport {
    pub fn wall_tok_s(&self) -> f64 {
        self.tokens_generated as f64 / self.wall_s.max(1e-9)
    }

    pub fn sim_tok_s(&self) -> f64 {
        self.tokens_generated as f64 / self.sim_s.max(1e-9)
    }

    pub fn summary(&self) -> String {
        format!(
            "{:<14} reqs={:<4} toks={:<6} iters={:<5} wall={:>7.2}s ({:>7.1} tok/s) \
             sim={:>7.3}s ({:>8.1} tok/s) acc/rnd={:>5.2} α={:>4.2} kv_util={:>4.2} \
             offl={} recomp={}",
            self.name,
            self.requests_done,
            self.tokens_generated,
            self.iterations,
            self.wall_s,
            self.wall_tok_s(),
            self.sim_s,
            self.sim_tok_s(),
            self.accept.mean_accepted(),
            self.accept.alpha(),
            self.mean_kv_util,
            self.kv.offload_events,
            self.kv.recomputed_tokens,
        )
    }
}
