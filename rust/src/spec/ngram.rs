//! N-gram drafter (the vLLM-NGram baseline): propose the continuation that
//! followed the longest matching suffix of the current context.
//!
//! Implementation: positional index from n-gram key -> last occurrence.
//! Matching prefers the longest suffix length from `max_n` down to 1;
//! proposals are copied verbatim from the history after the match point.

use std::collections::HashMap;

pub struct NGramIndex {
    pub max_n: usize,
    /// key (up to max_n tokens, packed) -> the two most recent positions
    /// AFTER the matched n-gram (continuation starts).  Two are kept
    /// because the newest entry is always the query suffix itself, which
    /// must not match itself.
    maps: Vec<HashMap<u64, (usize, usize)>>,
    history: Vec<i32>,
}

fn pack(window: &[i32]) -> u64 {
    // tokens < 2^16 in our vocab; pack up to 4 tokens into a u64 key.
    let mut k = 0u64;
    for &t in window {
        k = (k << 16) | (t as u64 & 0xFFFF);
    }
    k
}

impl NGramIndex {
    /// `max_n == 0` builds a disabled index: `extend` is a no-op and
    /// `propose` never matches.  Drafters that don't consult n-gram
    /// history (pillar/window/oracle/eagle/vanilla) use it so accepted
    /// tokens cost neither hashing nor history growth on the hot path.
    pub fn new(max_n: usize) -> Self {
        assert!(max_n <= 4, "packed key supports n in 0..=4");
        NGramIndex {
            max_n,
            maps: vec![HashMap::new(); max_n],
            history: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.history.len()
    }

    pub fn is_empty(&self) -> bool {
        self.history.is_empty()
    }

    /// Append accepted tokens to the indexed history.
    pub fn extend(&mut self, toks: &[i32]) {
        if self.max_n == 0 {
            return;
        }
        for &t in toks {
            self.history.push(t);
            let end = self.history.len();
            for n in 1..=self.max_n {
                if end >= n {
                    let key = pack(&self.history[end - n..end]);
                    let e = self.maps[n - 1].entry(key).or_insert((end, end));
                    *e = (e.1, end);
                }
            }
        }
    }

    /// Propose up to `k` continuation tokens for the current history.
    /// Returns an empty vec when no suffix of length >= 1 has occurred
    /// before (the engine then falls back to repeating the last token —
    /// matching vLLM's behaviour of drafting nothing useful).
    pub fn propose(&self, k: usize) -> Vec<i32> {
        let end = self.history.len();
        for n in (1..=self.max_n.min(end)).rev() {
            let key = pack(&self.history[end - n..end]);
            if let Some(&(prev, last)) = self.maps[n - 1].get(&key) {
                let cont = if last < end { last } else { prev };
                if cont < end {
                    let hi = (cont + k).min(end);
                    let mut out = self.history[cont..hi].to_vec();
                    // If the match is near the tail, wrap by cycling the
                    // available continuation (still a legitimate guess).
                    while out.len() < k && !out.is_empty() {
                        out.push(out[out.len() - 1]);
                    }
                    if !out.is_empty() {
                        return out;
                    }
                }
            }
        }
        Vec::new()
    }

    /// Rebuild from scratch (after preemption restarts a request).
    pub fn reset(&mut self) {
        self.history.clear();
        for m in &mut self.maps {
            m.clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ptest;

    #[test]
    fn proposes_repeated_pattern() {
        let mut ix = NGramIndex::new(3);
        // History "a b c d a b c d a b" -> suffix "a b" last continued by "c d a ..."
        ix.extend(&[10, 11, 12, 13, 10, 11, 12, 13, 10, 11]);
        let p = ix.propose(3);
        assert_eq!(p, vec![12, 13, 10]);
    }

    #[test]
    fn prefers_longest_suffix() {
        let mut ix = NGramIndex::new(3);
        // "x y z" occurred once continuing with 7; "z" most recently
        // continued with 9.  The longest-suffix match must win.
        ix.extend(&[1, 2, 3, 7, 5, 3, 9, 1, 2, 3]);
        let p = ix.propose(1);
        assert_eq!(p, vec![7]);
    }

    #[test]
    fn empty_history_proposes_nothing() {
        let ix = NGramIndex::new(3);
        assert!(ix.propose(4).is_empty());
    }

    #[test]
    fn novel_suffix_falls_back_to_shorter() {
        let mut ix = NGramIndex::new(3);
        ix.extend(&[5, 5, 5, 8]);
        // suffix "8" never continued before -> no proposal
        assert!(ix.propose(2).is_empty());
        ix.extend(&[5]);
        // suffix "...8 5": "5" was last continued by ... position after last
        // "5" key update is end itself; propose uses cont<end so earlier one.
        let p = ix.propose(2);
        assert!(!p.is_empty());
    }

    #[test]
    fn order_zero_is_inert() {
        let mut ix = NGramIndex::new(0);
        ix.extend(&[1, 2, 3, 1, 2, 3]);
        assert!(ix.is_empty(), "order-0 must not accumulate history");
        assert!(ix.propose(4).is_empty());
    }

    #[test]
    fn reset_clears() {
        let mut ix = NGramIndex::new(2);
        ix.extend(&[1, 2, 1, 2]);
        assert!(!ix.propose(1).is_empty());
        ix.reset();
        assert!(ix.propose(1).is_empty());
        assert!(ix.is_empty());
    }

    ptest!(proposals_come_from_history_alphabet, |g| {
        let mut ix = NGramIndex::new(g.usize(1, 4));
        let len = g.usize(1, 200);
        let alpha = g.usize(2, 8) as i64;
        let toks: Vec<i32> = (0..len).map(|_| g.i64(0, alpha - 1) as i32).collect();
        ix.extend(&toks);
        let k = g.usize(1, 8);
        let p = ix.propose(k);
        assert!(p.len() <= k);
        let set: std::collections::HashSet<i32> = toks.into_iter().collect();
        assert!(p.iter().all(|t| set.contains(t)), "proposal outside history");
    });

    ptest!(deterministic_history_perfect_proposals, |g| {
        // On a purely periodic sequence the n-gram drafter must predict
        // perfectly once it has seen one full period.
        let period = g.usize(2, 6);
        let reps = g.usize(3, 10);
        let pat: Vec<i32> = (0..period).map(|i| 100 + i as i32).collect();
        let mut ix = NGramIndex::new(2.min(period).max(1));
        let mut hist = Vec::new();
        for _ in 0..reps {
            hist.extend_from_slice(&pat);
        }
        ix.extend(&hist);
        let k = g.usize(1, period);
        let p = ix.propose(k);
        assert_eq!(p.len(), k);
        for (i, &t) in p.iter().enumerate() {
            assert_eq!(t, pat[(hist.len() + i) % period] , "mispredicted periodic token");
        }
    });
}
