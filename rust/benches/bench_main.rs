//! `cargo bench` entrypoint — regenerates every paper table/figure via the
//! shared harness (criterion is unavailable offline; this is the
//! from-scratch bench runner, see DESIGN.md §1).
//!
//! Select experiments: `cargo bench -- fig10 fig13` (default: all).


use sparsespec::bench::{run_named, BenchCtx};

/// The bench binary counts allocations so `engine_iteration` can enforce
/// its zero-steady-state-allocation gate (library builds keep the system
/// allocator; see `util::alloc`).
#[global_allocator]
static ALLOC: sparsespec::util::alloc::CountingAlloc = sparsespec::util::alloc::CountingAlloc;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let names: Vec<&str> = if args.is_empty() {
        vec!["all"]
    } else {
        args.iter().map(|s| s.as_str()).collect()
    };
    let artifacts = std::env::var("SPARSESPEC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let mut ctx = BenchCtx::new(&artifacts, "reports")?;
    if let Ok(n) = std::env::var("BENCH_REQUESTS") {
        ctx.n_requests = n.parse().unwrap_or(12);
    }
    for n in names {
        println!("\n================ {n} ================");
        run_named(&mut ctx, n)?;
    }
    Ok(())
}
