"""Fused sparse+full attention kernel (§4.2 "Fused sparse and full attention").

The paper's persistent CUDA kernel keeps one kernel resident and
dispatches each batch row to the template (tile shape / MMA config) best
suited to its phase — draft rows to the sparse gather template, verify
rows to the dense streaming template — recovering the bandwidth that a
one-size-fits-all launch ("Naive Batch") or two back-to-back launches
("Sequential") lose.

Pallas analogue: a single `pallas_call` whose grid walks a *worklist* of
rows; the per-row `kind` flag selects the code path inside the kernel.
Under interpret=True both paths are traced (XLA has no divergent branches)
so CPU wallclock does not show the win — the Fig. 15 comparison therefore
combines (a) this kernel for numerics, and (b) the launch/bytes cost model
in rust/src/perfmodel calibrated with the measured per-shape kernels
(python/compile/bench_kernels.py).  On a real TPU the dispatch is a
`lax.cond` over scalar-prefetched kind with genuinely different DMA
schedules per branch.

Contract == ref.fused_attn_ref.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF


def _kernel(q_ref, k_ref, v_ref, idx_ref, pos_ref, qv_ref, kind_ref,
            o_ref, dump_ref, *, group):
    q = q_ref[0]                      # [Q, Hq, D]
    k = k_ref[0]                      # [T, Hkv, D]
    v = v_ref[0]
    idx = idx_ref[0]                  # [Hkv, W]
    pos = pos_ref[0]
    q_valid = qv_ref[0]
    kind = kind_ref[0]

    Q, Hq, D = q.shape
    T, Hkv, _ = k.shape
    W = idx.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.array(D, dtype=q.dtype))
    qpos = pos + jnp.arange(Q)

    # --- sparse path (draft template) ----------------------------------
    safe = jnp.clip(idx, 0, T - 1)
    kg = jnp.take(k, safe.reshape(-1), axis=0).reshape(Hkv, W, Hkv, D)
    kg = kg[jnp.arange(Hkv), :, jnp.arange(Hkv)]
    vg = jnp.take(v, safe.reshape(-1), axis=0).reshape(Hkv, W, Hkv, D)
    vg = vg[jnp.arange(Hkv), :, jnp.arange(Hkv)]
    qh = q.reshape(Q, Hkv, group, D)
    lg_s = jnp.einsum("qhgd,hwd->qhgw", qh, kg) * scale
    vis = (idx[None, :, None, :] >= 0) & (
        idx[None, :, None, :] <= qpos[:, None, None, None]
    )
    lg_s = jnp.where(vis, lg_s, NEG_INF)
    e = jnp.exp(lg_s - jnp.max(lg_s, axis=-1, keepdims=True))
    p_s = e / jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)
    out_s = jnp.einsum("qhgw,hwd->qhgd", p_s, vg).reshape(Q, Hq, D)

    # --- dense path (verify template) -----------------------------------
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    lg_d = jnp.einsum("qhd,thd->qht", q, kx) * scale
    mask = jnp.arange(T)[None, None, :] <= qpos[:, None, None]
    lg_d = jnp.where(mask, lg_d, NEG_INF)
    m = jnp.max(lg_d, axis=-1, keepdims=True)
    ed = jnp.exp(lg_d - m)
    dd = jnp.maximum(jnp.sum(ed, axis=-1, keepdims=True), 1e-30)
    p_d = ed / dd
    out_d = jnp.einsum("qht,thd->qhd", p_d, vx)

    valid_q = (jnp.arange(Q) < q_valid).astype(q.dtype)
    nq = jnp.maximum(q_valid.astype(q.dtype), 1.0)
    pq = p_d * valid_q[:, None, None]
    dump = pq.reshape(Q, Hkv, group, T).sum(axis=(0, 2)) / (nq * group)

    kf = kind.astype(q.dtype)
    o_ref[0] = out_s * (1.0 - kf) + out_d * kf
    dump_ref[0] = dump * kf


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_attn(q, k_cache, v_cache, idx, pos, q_valid, kind, interpret=True):
    S, Q, Hq, D = q.shape
    _, T, Hkv, _ = k_cache.shape
    W = idx.shape[-1]
    group = Hq // Hkv
    return pl.pallas_call(
        functools.partial(_kernel, group=group),
        grid=(S,),
        in_specs=[
            pl.BlockSpec((1, Q, Hq, D), lambda s: (s, 0, 0, 0)),
            pl.BlockSpec((1, T, Hkv, D), lambda s: (s, 0, 0, 0)),
            pl.BlockSpec((1, T, Hkv, D), lambda s: (s, 0, 0, 0)),
            pl.BlockSpec((1, Hkv, W), lambda s: (s, 0, 0)),
            pl.BlockSpec((1,), lambda s: (s,)),
            pl.BlockSpec((1,), lambda s: (s,)),
            pl.BlockSpec((1,), lambda s: (s,)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, Hq, D), lambda s: (s, 0, 0, 0)),
            pl.BlockSpec((1, Hkv, T), lambda s: (s, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, Q, Hq, D), q.dtype),
            jax.ShapeDtypeStruct((S, Hkv, T), q.dtype),
        ],
        interpret=interpret,
    )(q, k_cache, v_cache, idx, pos, q_valid, kind)
