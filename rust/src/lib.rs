//! # SparseSpec — sparse self-speculative decoding for reasoning-model serving
//!
//! Reproduction of "Accelerating Large-Scale Reasoning Model Inference:
//! Self-Speculative Decoding with Sparse Attention" as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 1** (`python/compile/kernels/`): PillarAttn sparse attention,
//!   dense verification attention with zero-overhead score dumping, and the
//!   fused draft+verify kernel — Pallas, with pure-jnp oracles.
//! * **Layer 2** (`python/compile/model.py`): Qwen3-shaped decoder step
//!   functions, AOT-lowered once to HLO text (`make artifacts`).
//! * **Layer 3** (this crate): the serving coordinator — a **session-based
//!   streaming server** wrapping the unified batch scheduler, delayed
//!   verification, the dynamic two-tier KV-cache manager, PillarAttn
//!   critical-token state, all baselines, and the benchmark harness.
//!
//! ## Serving API (the front door)
//!
//! ```no_run
//! use std::rc::Rc;
//! use sparsespec::engine::{EngineConfig, EngineDriver, EngineHandle};
//! use sparsespec::runtime::Runtime;
//! use sparsespec::spec::DrafterKind;
//! use sparsespec::workload::{Dataset, WorkloadGen};
//!
//! # fn main() -> anyhow::Result<()> {
//! let rt = Rc::new(Runtime::load("artifacts")?);
//! let cfg = EngineConfig::builder(DrafterKind::Pillar { w: 128 })
//!     .k(8)
//!     .build(&rt.cfg.model)?;                       // validated up front
//! let gen = WorkloadGen::new(rt.cfg.grammar.clone(), rt.cfg.model.clone(),
//!                            Dataset::Aime, 42);
//! let mut driver = EngineDriver::with_arrivals(
//!     EngineHandle::new(rt, cfg)?,
//!     gen.online_arrivals(2.0, 30.0),               // live Poisson arrivals
//! );
//! while driver.step()? {
//!     for sess in driver.sessions() {
//!         for tok in sess.drain() {                  // incremental tokens
//!             let _ = tok;
//!         }
//!     }
//! }
//! let report = driver.report();
//! # let _ = report; Ok(())
//! # }
//! ```
//!
//! Sessions stream tokens as verification accepts them, expose TTFT /
//! inter-token / acceptance stats, and can be cancelled mid-generation;
//! `Engine::run(Vec<Request>)` survives as a batch-compatibility wrapper
//! with bit-identical outputs.  See `engine::api` for the full surface.
//! The [`serving`] module takes the same surface across the process
//! boundary: `sparsespec-server` exposes submit/stream/cancel over TCP
//! (admission control, backpressure, per-tenant fairness) and
//! `sparsespec-client` replays open-loop workload traffic against it —
//! see EXPERIMENTS.md §Serving.
//!
//! ## Observability
//!
//! [`trace`] provides span-based structured tracing on both the simulated
//! serving clock and the wall clock, exported as Chrome/Perfetto trace
//! JSON (`--trace-out`, `EngineConfig::builder().tracing(...)`);
//! [`metrics::MetricsRegistry`] is the typed, labelled, mergeable metrics
//! store behind Prometheus-style exposition and the SLO section of
//! [`engine::RunReport`].  See EXPERIMENTS.md §Observability.
//!
//! ## Drafters are plugins
//!
//! Every draft policy — PillarAttn, sliding window, n-gram, EAGLE,
//! TriForce, oracle, vanilla — implements the object-safe
//! [`spec::Drafter`] trait and resolves through a
//! [`spec::DrafterRegistry`]; out-of-crate drafters register a
//! constructor and never touch the engine (`Engine::with_registry`).
//! Sessions pick their drafter per request (`Request::drafter`), one
//! engine serves the mixed batch with per-drafter acceptance breakdowns
//! (`RunReport::accept_by`), and `EngineConfig::adaptive_k` layers the
//! feedback-adaptive speculation-length controller ([`spec::adaptive`])
//! on any of them.  See `spec::drafter` for a worked "write your own
//! drafter" example.
//!
//! ## Robustness
//!
//! Speculation is a pure accelerator, and the failure story keeps it one:
//! fallible paths return the typed [`fault::EngineError`] taxonomy
//! (transient errors retry with sim-clock backoff; fatal ones isolate),
//! drafter hooks run inside a `catch_unwind` sandbox with proposal-shape
//! validation, and misbehaving slots demote to vanilla (k=1) decoding
//! with a probation window — sessions finish `Completed`, just slower.
//! A deterministic, seed-driven [`fault::FaultInjector`] (`--fault-plan`,
//! `--fault-seed`) drives the chaos suite (`rust/tests/chaos.rs`), whose
//! invariant is that co-batched unaffected sessions stay bit-identical
//! to a fault-free run.  See EXPERIMENTS.md §Robustness.
//!
//! ## Execution backends
//!
//! The default build serves through a **deterministic CPU fallback
//! runtime** (`runtime::sim`) — no artifacts, no native deps, bit-stable
//! across machines — so a fresh checkout builds, tests and demos with
//! plain `cargo build && cargo test`.  Enable `--features pjrt` (with the
//! patched `xla` crate vendored under `rust/vendor/xla` and `make
//! artifacts` run) for the real path: the Rust binary loads the HLO
//! artifacts through PJRT and owns the entire serving loop — Python never
//! runs on the request path.

// Lint posture lives in Cargo.toml's [lints.clippy] table so it covers
// every target (lib, bin, tests, examples, benches) from one place.

pub mod bench;
pub mod engine;
pub mod fault;
pub mod kv_cache;
pub mod metrics;
pub mod model;
pub mod perfmodel;
pub mod runtime;
pub mod sampling;
pub mod scheduler;
pub mod serving;
pub mod spec;
pub mod trace;
pub mod util;
pub mod workload;
