//! Typed view over `artifacts/config.json`.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Mirror of python/compile/config.py::ModelConfig.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub q_heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub ffn: usize,
    pub max_seq: usize,
    pub slots: usize,
    pub prompt_pad: usize,
    pub spec_k: usize,
    pub draft_budget: usize,
    pub verify_q_variants: Vec<usize>,
    pub draft_w_variants: Vec<usize>,
}

impl ModelConfig {
    /// KV-cache bytes for one token (all layers, K+V, f32).
    pub fn kv_bytes_per_token(&self) -> usize {
        self.layers * 2 * self.kv_heads * self.head_dim * 4
    }

    /// Elements of one slot row set [L, T, Hkv, D] (one of K or V).
    pub fn kv_slot_elems(&self) -> usize {
        self.layers * self.max_seq * self.kv_heads * self.head_dim
    }

    /// Elements of the whole pool [L, S, T, Hkv, D].
    pub fn kv_pool_elems(&self) -> usize {
        self.kv_slot_elems() * self.slots
    }

    /// Is a `verify_q{q}` artifact variant compiled?
    pub fn has_verify_q(&self, q: usize) -> bool {
        self.verify_q_variants.contains(&q)
    }

    /// Is a `draft_w{w}` artifact variant compiled?
    pub fn has_draft_w(&self, w: usize) -> bool {
        self.draft_w_variants.contains(&w)
    }
}

/// Mirror of python/compile/config.py::GrammarConfig (the synthetic
/// reasoning-trace language; must stay bit-identical to the Python side —
/// golden tests pin both).
#[derive(Clone, Debug)]
pub struct GrammarConfig {
    pub pad: i32,
    pub bos: i32,
    pub eos: i32,
    pub def_tok: i32,
    pub qry: i32,
    pub eq: i32,
    pub sep: i32,
    pub slot_base: i32,
    pub n_slots: i32,
    pub value_base: i32,
    pub n_values: i32,
    pub filler_base: i32,
    pub n_filler: i32,
    pub mode_base: i32,
    pub n_modes: i32,
    pub n_defs: i32,
    pub redefine_prob: f64,
    pub query_prob: f64,
    pub focus_query_prob: f64,
    pub focus_switch_prob: f64,
    pub mode_mul: Vec<i32>,
    pub mode_add: Vec<i32>,
}

#[derive(Clone, Debug)]
pub struct EagleConfig {
    pub ctx: usize,
    pub embed: usize,
    pub hidden: usize,
}

#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub file: String,
    pub args: Vec<Vec<usize>>,
}

/// Everything `config.json` carries.
#[derive(Clone, Debug)]
pub struct SystemConfig {
    pub model: ModelConfig,
    pub grammar: GrammarConfig,
    pub eagle: EagleConfig,
    pub n_params: usize,
    pub eagle_n_params: usize,
    pub trained: bool,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
    pub dir: String,
}

fn req_usize(j: &Json, path: &[&str]) -> Result<usize> {
    j.at(path)
        .and_then(|v| v.as_usize())
        .ok_or_else(|| anyhow!("config.json missing {}", path.join(".")))
}

fn req_i32(j: &Json, path: &[&str]) -> Result<i32> {
    Ok(req_usize(j, path)? as i32)
}

fn req_f64(j: &Json, path: &[&str]) -> Result<f64> {
    j.at(path)
        .and_then(|v| v.as_f64())
        .ok_or_else(|| anyhow!("config.json missing {}", path.join(".")))
}

fn i32_list(j: &Json, path: &[&str]) -> Result<Vec<i32>> {
    Ok(j.at(path)
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("config.json missing {}", path.join(".")))?
        .iter()
        .filter_map(|x| x.as_i64().map(|n| n as i32))
        .collect())
}

impl SystemConfig {
    /// The built-in testbed configuration — the exact shape
    /// `python/compile/config.py` emits for this environment.  Used by the
    /// deterministic fallback runtime when no `artifacts/config.json`
    /// exists, so the crate serves from a fresh checkout; when artifacts
    /// *are* present their config takes precedence.
    pub fn synthetic(dir: &str) -> SystemConfig {
        SystemConfig {
            model: ModelConfig {
                vocab: 512,
                hidden: 128,
                layers: 4,
                q_heads: 4,
                kv_heads: 2,
                head_dim: 32,
                ffn: 256,
                max_seq: 512,
                slots: 12,
                prompt_pad: 32,
                spec_k: 8,
                draft_budget: 64,
                verify_q_variants: vec![1, 5, 9, 13, 17, 21],
                draft_w_variants: vec![16, 32, 64, 128, 256],
            },
            grammar: GrammarConfig {
                pad: 0,
                bos: 1,
                eos: 2,
                def_tok: 3,
                qry: 4,
                eq: 5,
                sep: 6,
                slot_base: 16,
                n_slots: 48,
                value_base: 80,
                n_values: 256,
                filler_base: 336,
                n_filler: 120,
                mode_base: 456,
                n_modes: 12,
                n_defs: 8,
                redefine_prob: 0.08,
                query_prob: 0.30,
                focus_query_prob: 0.85,
                focus_switch_prob: 0.18,
                mode_mul: vec![1, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43],
                mode_add: vec![3, 8, 1, 14, 5, 11, 2, 7, 9, 4, 13, 6],
            },
            eagle: EagleConfig { ctx: 4, embed: 32, hidden: 128 },
            n_params: 656_512,
            eagle_n_params: 82_432,
            trained: false,
            artifacts: BTreeMap::new(),
            dir: dir.to_string(),
        }
    }

    pub fn load(dir: &str) -> Result<SystemConfig> {
        let path = Path::new(dir).join("config.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).context("parsing config.json")?;
        Self::from_json(&j, dir)
    }

    pub fn from_json(j: &Json, dir: &str) -> Result<SystemConfig> {
        let usize_list = |p: &[&str]| -> Result<Vec<usize>> {
            Ok(j.at(p)
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("missing {}", p.join(".")))?
                .iter()
                .filter_map(|x| x.as_usize())
                .collect())
        };
        let model = ModelConfig {
            vocab: req_usize(j, &["model", "vocab"])?,
            hidden: req_usize(j, &["model", "hidden"])?,
            layers: req_usize(j, &["model", "layers"])?,
            q_heads: req_usize(j, &["model", "q_heads"])?,
            kv_heads: req_usize(j, &["model", "kv_heads"])?,
            head_dim: req_usize(j, &["model", "head_dim"])?,
            ffn: req_usize(j, &["model", "ffn"])?,
            max_seq: req_usize(j, &["model", "max_seq"])?,
            slots: req_usize(j, &["model", "slots"])?,
            prompt_pad: req_usize(j, &["model", "prompt_pad"])?,
            spec_k: req_usize(j, &["model", "spec_k"])?,
            draft_budget: req_usize(j, &["model", "draft_budget"])?,
            verify_q_variants: usize_list(&["model", "verify_q_variants"])?,
            draft_w_variants: usize_list(&["model", "draft_w_variants"])?,
        };
        let grammar = GrammarConfig {
            pad: req_i32(j, &["grammar", "pad"])?,
            bos: req_i32(j, &["grammar", "bos"])?,
            eos: req_i32(j, &["grammar", "eos"])?,
            def_tok: req_i32(j, &["grammar", "def_tok"])?,
            qry: req_i32(j, &["grammar", "qry"])?,
            eq: req_i32(j, &["grammar", "eq"])?,
            sep: req_i32(j, &["grammar", "sep"])?,
            slot_base: req_i32(j, &["grammar", "slot_base"])?,
            n_slots: req_i32(j, &["grammar", "n_slots"])?,
            value_base: req_i32(j, &["grammar", "value_base"])?,
            n_values: req_i32(j, &["grammar", "n_values"])?,
            filler_base: req_i32(j, &["grammar", "filler_base"])?,
            n_filler: req_i32(j, &["grammar", "n_filler"])?,
            mode_base: req_i32(j, &["grammar", "mode_base"])?,
            n_modes: req_i32(j, &["grammar", "n_modes"])?,
            n_defs: req_i32(j, &["grammar", "n_defs"])?,
            redefine_prob: req_f64(j, &["grammar", "redefine_prob"])?,
            query_prob: req_f64(j, &["grammar", "query_prob"])?,
            focus_query_prob: req_f64(j, &["grammar", "focus_query_prob"])?,
            focus_switch_prob: req_f64(j, &["grammar", "focus_switch_prob"])?,
            mode_mul: i32_list(j, &["grammar", "mode_mul"])?,
            mode_add: i32_list(j, &["grammar", "mode_add"])?,
        };
        let eagle = EagleConfig {
            ctx: req_usize(j, &["eagle", "ctx"])?,
            embed: req_usize(j, &["eagle", "embed"])?,
            hidden: req_usize(j, &["eagle", "hidden"])?,
        };
        let mut artifacts = BTreeMap::new();
        if let Some(Json::Obj(m)) = j.get("artifacts") {
            for (name, info) in m {
                let file = info
                    .get("file")
                    .and_then(|f| f.as_str())
                    .ok_or_else(|| anyhow!("artifact {name} missing file"))?
                    .to_string();
                let args = info
                    .get("args")
                    .and_then(|a| a.as_arr())
                    .map(|arr| {
                        arr.iter()
                            .map(|shape| {
                                shape
                                    .as_arr()
                                    .unwrap_or(&[])
                                    .iter()
                                    .filter_map(|d| d.as_usize())
                                    .collect()
                            })
                            .collect()
                    })
                    .unwrap_or_default();
                artifacts.insert(name.clone(), ArtifactInfo { file, args });
            }
        }
        Ok(SystemConfig {
            model,
            grammar,
            eagle,
            n_params: req_usize(j, &["n_params"])?,
            eagle_n_params: req_usize(j, &["eagle_n_params"])?,
            trained: j.get("trained").and_then(|v| v.as_bool()).unwrap_or(false),
            artifacts,
            dir: dir.to_string(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_json() -> Json {
        Json::parse(
            r#"{
          "model": {"vocab":512,"hidden":128,"layers":4,"q_heads":4,"kv_heads":2,
            "head_dim":32,"ffn":256,"rope_theta":10000.0,"rms_eps":1e-5,
            "max_seq":512,"slots":12,"prompt_pad":32,"spec_k":8,"draft_budget":64,
            "verify_q_variants":[5,9,13,17,21],"draft_w_variants":[16,32,64,128,256]},
          "grammar": {"pad":0,"bos":1,"eos":2,"def_tok":3,"qry":4,"eq":5,"sep":6,
            "slot_base":16,"n_slots":48,"value_base":80,"n_values":256,
            "filler_base":336,"n_filler":120,"mode_base":456,"n_modes":12,
            "n_defs":8,"redefine_prob":0.08,"query_prob":0.30,
            "focus_query_prob":0.85,"focus_switch_prob":0.18,
            "mode_mul":[1,7,11,13,17,19,23,29,31,37,41,43],
            "mode_add":[3,8,1,14,5,11,2,7,9,4,13,6]},
          "eagle": {"ctx":4,"embed":32,"hidden":128},
          "n_params": 656512, "eagle_n_params": 123,
          "trained": true,
          "artifacts": {"prefill": {"file":"prefill.hlo.txt","args":[[656512],[4,12,512,2,32]]}}
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_config() {
        let c = SystemConfig::from_json(&fake_json(), "/tmp").unwrap();
        assert_eq!(c.model.hidden, 128);
        assert_eq!(c.model.verify_q_variants, vec![5, 9, 13, 17, 21]);
        assert_eq!(c.grammar.n_defs, 8);
        assert!(c.trained);
        assert_eq!(c.artifacts["prefill"].args[1], vec![4, 12, 512, 2, 32]);
        // KV math: 4 layers * 2 * 2 heads * 32 dim * 4 B = 2 KiB per token
        assert_eq!(c.model.kv_bytes_per_token(), 2048);
        // variant lookups used by drafter validation
        assert!(c.model.has_verify_q(9) && !c.model.has_verify_q(8));
        assert!(c.model.has_draft_w(64) && !c.model.has_draft_w(63));
    }

    #[test]
    fn synthetic_is_self_consistent() {
        let c = SystemConfig::synthetic("artifacts");
        assert_eq!(c.model.kv_bytes_per_token(), 2048);
        // the engine's default k and every drafter budget must have a
        // matching artifact variant, or the fallback runtime rejects them
        assert!(c.model.verify_q_variants.contains(&(c.model.spec_k + 1)));
        assert!(c.model.verify_q_variants.contains(&1));
        assert!(c.model.draft_w_variants.contains(&c.model.draft_budget));
        assert!(c.artifacts.is_empty());
    }

    #[test]
    fn missing_field_is_error() {
        let j = Json::parse(r#"{"model": {"vocab": 512}}"#).unwrap();
        assert!(SystemConfig::from_json(&j, "/tmp").is_err());
    }
}
