//! sparsespec-router — scale-out front door over N server replicas.
//!
//! Speaks wire v1 upstream (an unchanged `sparsespec-client` connects to
//! it like any server) and downstream (each replica sees an ordinary
//! client).  Two ways to get a fleet:
//!
//!   attach mode — replicas already running:
//!     sparsespec-router --listen 127.0.0.1:7533 --metrics-addr 127.0.0.1:7534 \
//!         --replicas 127.0.0.1:7433@127.0.0.1:7434,127.0.0.1:7443@127.0.0.1:7444
//!
//!   spawn mode — launch the replicas as child processes (ephemeral
//!   ports, addresses parsed from their stdout), forwarding any extra
//!   engine flags verbatim:
//!     sparsespec-router --spawn 2 --listen 127.0.0.1:7533 \
//!         --metrics-addr 127.0.0.1:7534 -- --drafter pillar --k 8
//!
//! Fleet `/metrics` serves the one-merge rollup of every replica's
//! `/snapshot` plus the router's own routing/health series.

use std::io::BufRead;
use std::process::{Child, Command, Stdio};

use sparsespec::serving::router::{ReplicaSpec, Router, RouterConfig};
use sparsespec::util::cli::Args;

fn usage() -> ! {
    eprintln!(
        "usage: sparsespec-router [flags] [-- server-flags...]\n\
         \x20 --replicas SPEC        addr[@metrics_addr],...  attach to running replicas\n\
         \x20 --spawn N              launch N sparsespec-server children instead (ephemeral ports);\n\
         \x20                        flags after `--` are passed through to each child\n\
         \x20 --listen ADDR          upstream listen address (default 127.0.0.1:7533; port 0 = ephemeral)\n\
         \x20 --metrics-addr ADDR    fleet /metrics + /snapshot HTTP address (off unless given)\n\
         \x20 --send-window N        per-client token credit window (default 1024)\n\
         \x20 --bucket-edges SPEC    ascending KV-cost bucket bounds (default 128,256,512)\n\
         \x20 --ping-every-ms N      health-check ping period (default 500)\n\
         \x20 --down-after N         unanswered pings before a replica is Down (default 3)\n\
         \x20 --rollup-every-ms N    fleet metrics refresh period (default 200)\n\
         \x20 --trace-out FILE       export the routing Perfetto trace on drain\n\
         \x20 --metrics-out FILE     save the final fleet exposition on drain"
    );
    std::process::exit(2)
}

fn parse_replicas(spec: &str) -> Option<Vec<ReplicaSpec>> {
    let mut out = Vec::new();
    for part in spec.split(',').filter(|p| !p.is_empty()) {
        let (addr, metrics) = match part.split_once('@') {
            Some((a, m)) => (a.to_string(), Some(m.to_string())),
            None => (part.to_string(), None),
        };
        if addr.is_empty() {
            return None;
        }
        out.push(ReplicaSpec { addr, metrics_addr: metrics });
    }
    if out.is_empty() {
        None
    } else {
        Some(out)
    }
}

fn parse_edges(spec: &str) -> Option<Vec<usize>> {
    spec.split(',').filter(|p| !p.is_empty()).map(|p| p.parse().ok()).collect()
}

/// Launch one `sparsespec-server` child on ephemeral ports and scrape its
/// bound addresses from stdout ("sparsespec-server listening on ADDR" /
/// "metrics on http://ADDR/metrics").
fn spawn_replica(i: usize, passthrough: &[String]) -> anyhow::Result<(Child, ReplicaSpec)> {
    let me = std::env::current_exe()?;
    let server_bin = me
        .parent()
        .map(|d| d.join("sparsespec-server"))
        .filter(|p| p.exists())
        .ok_or_else(|| anyhow::anyhow!("sparsespec-server not found next to {}", me.display()))?;
    let mut child = Command::new(&server_bin)
        .arg("--listen")
        .arg("127.0.0.1:0")
        .arg("--metrics-addr")
        .arg("127.0.0.1:0")
        .arg("--replica-id")
        .arg(i.to_string())
        .args(passthrough)
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()?;
    let stdout = child.stdout.take().expect("piped stdout");
    let mut reader = std::io::BufReader::new(stdout);
    let mut addr = None;
    let mut metrics = None;
    let mut line = String::new();
    while (addr.is_none() || metrics.is_none()) && {
        line.clear();
        reader.read_line(&mut line)? > 0
    } {
        let l = line.trim();
        if let Some(rest) = l.strip_prefix("sparsespec-server listening on ") {
            addr = Some(rest.to_string());
        } else if let Some(rest) = l.strip_prefix("metrics on http://") {
            metrics = Some(rest.trim_end_matches("/metrics").to_string());
        }
    }
    // keep draining the child's stdout so its prints never block it
    std::thread::spawn(move || {
        let mut sink = String::new();
        while reader.read_line(&mut sink).map(|n| n > 0).unwrap_or(false) {
            print!("replica {i}: {sink}");
            sink.clear();
        }
    });
    let addr = addr.ok_or_else(|| anyhow::anyhow!("replica {i}: no listen address on stdout"))?;
    Ok((child, ReplicaSpec { addr, metrics_addr: metrics }))
}

fn main() -> anyhow::Result<()> {
    // split off `-- server-flags...` before normal flag parsing
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (own, passthrough) = match argv.iter().position(|a| a == "--") {
        Some(i) => (argv[..i].to_vec(), argv[i + 1..].to_vec()),
        None => (argv, Vec::new()),
    };
    let args = Args::parse(own);
    if args.bool("help", false) {
        usage();
    }

    let mut children: Vec<Child> = Vec::new();
    let replicas = if let Some(n) = args.opt("spawn") {
        let n: usize = n.parse().unwrap_or_else(|_| usage());
        if n == 0 {
            usage();
        }
        let mut specs = Vec::new();
        for i in 0..n {
            let (child, spec) = spawn_replica(i, &passthrough)?;
            println!(
                "router: replica {i} pid={} addr={} metrics={}",
                child.id(),
                spec.addr,
                spec.metrics_addr.as_deref().unwrap_or("n/a")
            );
            children.push(child);
            specs.push(spec);
        }
        specs
    } else {
        match args.opt("replicas").and_then(parse_replicas) {
            Some(r) => r,
            None => usage(),
        }
    };

    let mut cfg = RouterConfig::new(replicas);
    cfg.addr = args.str("listen", "127.0.0.1:7533");
    cfg.metrics_addr = args.opt("metrics-addr").map(|s| s.to_string());
    cfg.send_window = args.u64("send-window", 1024) as u32;
    cfg.send_queue_cap = cfg.send_window as usize + 64;
    if let Some(spec) = args.opt("bucket-edges") {
        cfg.bucket_edges = parse_edges(spec).unwrap_or_else(|| usage());
    }
    cfg.ping_every_ms = args.u64("ping-every-ms", 500);
    cfg.down_after_missed = args.u64("down-after", 3) as u32;
    cfg.rollup_every_ms = args.u64("rollup-every-ms", 200);
    cfg.trace_out = args.opt("trace-out").map(|s| s.to_string());

    let router = Router::spawn(cfg)?;
    println!("sparsespec-router listening on {}", router.addr());
    if let Some(m) = router.metrics_addr() {
        println!("fleet metrics on http://{m}/metrics");
    }
    println!("(drain with the wire Shutdown frame, e.g. sparsespec-client --shutdown)");

    let summary = router.join()?;
    println!(
        "fleet drained: routed={} resubmitted={} failed_over={}",
        summary.routed, summary.resubmitted, summary.failed_over
    );
    if let Some(path) = args.opt("metrics-out") {
        std::fs::write(path, &summary.exposition)?;
        println!("fleet metrics exposition saved to {path}");
    }
    for mut child in children {
        // the drain already forwarded Shutdown; reap the replicas
        let _ = child.wait();
    }
    Ok(())
}
