"""Dense verification attention with score dumping, as a Pallas kernel.

This is the verification-phase half of PillarAttn's *zero-overhead
identification* (§4.1): the same kernel that verifies the k drafted tokens
dumps, per cache position, the attention mass the drafted queries put on
it.  The dump is the Top-K input for the next k draft steps — no extra
memory pass over the KV-cache is ever made.

Implementation is a two-pass flash-decoding scheme over KV tiles:
  pass 1  online softmax statistics (running max m, denominator d) per
          (query, head) — this is the LSE the paper caches;
  pass 2  *rematerialises* probabilities tile-by-tile from the cached
          logits/LSE (exactly the paper's "attention logits and logarithm
          summation of exponential are cached ... used to rematerialize
          attention scores"), accumulating the output PV product and the
          per-position score dump.

TPU mapping: grid=(S,), KV tiles of TILE=128 rows live in VMEM
(128 x Hkv x D f32 = 32 KiB per tile), the MXU consumes the QK^T / PV
einsums; pass 2's recompute trades FLOPs (cheap on MXU) for not keeping
[Q, Hq, T] probabilities resident.  interpret=True for CPU execution.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF

TILE = 128


def _kernel(q_ref, k_ref, v_ref, pos_ref, qv_ref, o_ref, dump_ref, lse_ref, *, group):
    q = q_ref[0]                        # [Q, Hq, D]
    k = k_ref[0]                        # [T, Hkv, D]
    v = v_ref[0]
    pos = pos_ref[0]
    q_valid = qv_ref[0]

    Q, Hq, D = q.shape
    T, Hkv, _ = k.shape
    scale = 1.0 / jnp.sqrt(jnp.array(D, dtype=q.dtype))
    qpos = pos + jnp.arange(Q)                              # [Q]
    n_tiles = T // TILE

    def tile_logits(t0, kt):
        """logits for one KV tile: [Q, Hq, TILE] (causal-masked)."""
        kx = jnp.repeat(kt, group, axis=1)                  # [TILE, Hq, D]
        lg = jnp.einsum("qhd,thd->qht", q, kx) * scale
        tpos = t0 + jnp.arange(TILE)
        mask = tpos[None, None, :] <= qpos[:, None, None]
        return jnp.where(mask, lg, NEG_INF)

    # ---- pass 1: online softmax statistics (flash) --------------------
    m = jnp.full((Q, Hq), NEG_INF, dtype=q.dtype)
    d = jnp.zeros((Q, Hq), dtype=q.dtype)
    for i in range(n_tiles):
        lg = tile_logits(i * TILE, k[i * TILE : (i + 1) * TILE])
        mt = jnp.max(lg, axis=-1)
        m_new = jnp.maximum(m, mt)
        d = d * jnp.exp(m - m_new) + jnp.sum(jnp.exp(lg - m_new[..., None]), axis=-1)
        m = m_new
    d = jnp.maximum(d, 1e-30)
    lse_ref[0] = m + jnp.log(d)                             # [Q, Hq]

    # ---- pass 2: rematerialise probs, accumulate out + dump -----------
    valid_q = (jnp.arange(Q) < q_valid).astype(q.dtype)     # [Q]
    nq = jnp.maximum(q_valid.astype(q.dtype), 1.0)
    acc = jnp.zeros((Q, Hq, D), dtype=q.dtype)
    for i in range(n_tiles):
        kt = k[i * TILE : (i + 1) * TILE]
        vt = jnp.repeat(v[i * TILE : (i + 1) * TILE], group, axis=1)
        lg = tile_logits(i * TILE, kt)
        p = jnp.exp(lg - m[..., None]) / d[..., None]       # [Q, Hq, TILE]
        acc = acc + jnp.einsum("qht,thd->qhd", p, vt)
        pq = p * valid_q[:, None, None]
        dump_t = pq.reshape(Q, Hkv, group, TILE).sum(axis=(0, 2)) / (nq * group)
        dump_ref[0, :, i * TILE : (i + 1) * TILE] = dump_t
    o_ref[0] = acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def full_attn(q, k_cache, v_cache, pos, q_valid, interpret=True):
    """Pallas verification attention. Contract == ref.full_attn_ref."""
    S, Q, Hq, D = q.shape
    _, T, Hkv, _ = k_cache.shape
    group = Hq // Hkv
    assert T % TILE == 0, "max_seq must be a multiple of the KV tile"
    return pl.pallas_call(
        functools.partial(_kernel, group=group),
        grid=(S,),
        in_specs=[
            pl.BlockSpec((1, Q, Hq, D), lambda s: (s, 0, 0, 0)),
            pl.BlockSpec((1, T, Hkv, D), lambda s: (s, 0, 0, 0)),
            pl.BlockSpec((1, T, Hkv, D), lambda s: (s, 0, 0, 0)),
            pl.BlockSpec((1,), lambda s: (s,)),
            pl.BlockSpec((1,), lambda s: (s,)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, Hq, D), lambda s: (s, 0, 0, 0)),
            pl.BlockSpec((1, Hkv, T), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, Q, Hq), lambda s: (s, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, Q, Hq, D), q.dtype),
            jax.ShapeDtypeStruct((S, Hkv, T), q.dtype),
            jax.ShapeDtypeStruct((S, Q, Hq), q.dtype),
        ],
        interpret=interpret,
    )(q, k_cache, v_cache, pos, q_valid)


def vmem_bytes(Q, Hq, Hkv, D, T, dtype_bytes=4):
    """VMEM working set per grid step (tile-resident variant; full cache
    streams through TILE-row windows)."""
    q = Q * Hq * D
    kv_tile = 2 * TILE * Hkv * D
    logits = Q * Hq * TILE
    acc = Q * Hq * D + Q * Hq * 2      # out + (m, d)
    dump_tile = Hkv * TILE
    return (q + kv_tile + logits + acc + dump_tile) * dtype_bytes
