//! Step-function accounting types shared by every runtime backend.

use std::collections::BTreeMap;

/// Per-artifact cumulative timing, split into the three phases the paper's
/// Table 2 cares about: CPU marshalling (upload), device execution, fetch.
#[derive(Clone, Debug, Default)]
pub struct StepStats {
    pub per_artifact: BTreeMap<String, PhaseTimes>,
}

#[derive(Clone, Debug, Default)]
pub struct PhaseTimes {
    pub calls: u64,
    pub upload_s: f64,
    pub exec_s: f64,
    pub fetch_s: f64,
}

impl StepStats {
    pub(crate) fn add(&mut self, name: &str, upload: f64, exec: f64, fetch: f64) {
        let e = self.per_artifact.entry(name.to_string()).or_default();
        e.calls += 1;
        e.upload_s += upload;
        e.exec_s += exec;
        e.fetch_s += fetch;
    }

    /// Attribute host-side CPU work to a named pseudo-artifact (e.g.
    /// `pillar_select` for critical-token selection), so Table-2 style
    /// phase breakdowns and the delayed-verify overlap model see it.
    pub fn note_host(&mut self, name: &str, secs: f64) {
        self.add(name, secs, 0.0, 0.0);
    }

    pub fn total_exec(&self) -> f64 {
        self.per_artifact.values().map(|p| p.exec_s).sum()
    }

    pub fn total_cpu(&self) -> f64 {
        self.per_artifact
            .values()
            .map(|p| p.upload_s + p.fetch_s)
            .sum()
    }
}

pub struct VerifyOut {
    /// [S, Q, V] flattened.
    pub logits: Vec<f32>,
    /// [S, L, Hkv, T] flattened attention-mass dump (PillarAttn input).
    pub dump: Vec<f32>,
}

pub struct DraftOut {
    /// [S, V] flattened.
    pub logits: Vec<f32>,
}
