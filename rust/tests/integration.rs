//! Integration tests over the full stack: runtime, engine rounds, every
//! drafter, KV policies, schedules — and the paper's core *losslessness*
//! invariant: greedy speculative decoding reproduces vanilla outputs
//! token-for-token, for every drafter.
//!
//! They run against whichever backend the build selected: the default
//! deterministic CPU fallback needs no artifacts at all; with
//! `--features pjrt` the same tests exercise the real AOT artifacts
//! (requires `make artifacts`).  The Pallas compose-proof at the bottom is
//! pjrt-only.


use std::rc::Rc;

use sparsespec::engine::{Engine, EngineConfig};
use sparsespec::kv_cache::KvPolicy;
use sparsespec::runtime::{ModelRunner, Runtime};
use sparsespec::scheduler::Schedule;
use sparsespec::spec::DrafterKind;
use sparsespec::workload::{Dataset, WorkloadGen};

fn artifacts_dir() -> String {
    std::env::var("SPARSESPEC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

fn runtime() -> Rc<Runtime> {
    Rc::new(Runtime::load(&artifacts_dir()).expect("runtime loads (pjrt builds need `make artifacts`)"))
}

fn requests(rt: &Runtime, ds: Dataset, n: usize, seed: u64) -> Vec<sparsespec::workload::Request> {
    WorkloadGen::new(rt.cfg.grammar.clone(), rt.cfg.model.clone(), ds, seed).offline_batch(n)
}

/// Shorten request budgets so integration tests stay fast.
fn small_requests(rt: &Runtime, n: usize, cap: usize) -> Vec<sparsespec::workload::Request> {
    let mut reqs = requests(rt, Dataset::Aime, n, 99);
    for r in &mut reqs {
        r.max_new = r.max_new.min(cap);
    }
    reqs
}

#[test]
fn runtime_loads_and_executes_verify() {
    let rt = runtime();
    let m = rt.cfg.model.clone();
    let mut runner = ModelRunner::new(rt.clone()).unwrap();
    runner
        .prefill(
            &vec![5i32; m.slots * m.prompt_pad],
            &vec![4i32; m.slots],
            &vec![1i32; m.slots],
        )
        .unwrap();
    let logits = runner.logits();
    assert_eq!(logits.len(), m.slots * m.vocab);
    assert!(logits.iter().all(|x| x.is_finite()));
}

#[test]
fn vanilla_decode_is_deterministic() {
    let rt = runtime();
    let run = |seed| {
        let mut eng = Engine::new(rt.clone(), EngineConfig::new(DrafterKind::Vanilla)).unwrap();
        let mut reqs = small_requests(&rt, 3, 40);
        for r in &mut reqs {
            r.id += seed;
        }
        eng.run(reqs).unwrap()
    };
    let a = run(0);
    let b = run(0);
    assert_eq!(a.tokens_generated, b.tokens_generated);
    for (x, y) in a.outputs.values().zip(b.outputs.values()) {
        assert_eq!(x, y);
    }
}

/// THE paper invariant: every speculative drafter is lossless under greedy
/// decoding — outputs must equal the vanilla outputs exactly.
#[test]
fn all_drafters_are_lossless() {
    let rt = runtime();
    let reqs = small_requests(&rt, 4, 48);
    let mut vanilla = Engine::new(rt.clone(), EngineConfig::new(DrafterKind::Vanilla)).unwrap();
    let base = vanilla.run(reqs.clone()).unwrap();
    for drafter in [
        DrafterKind::Pillar { w: 64 },
        DrafterKind::Window { w: 64 },
        DrafterKind::NGram { n: 3 },
        DrafterKind::Eagle,
        DrafterKind::TriForce { w: 64 },
    ] {
        let mut eng = Engine::new(rt.clone(), EngineConfig::new(drafter).with_k(8)).unwrap();
        let r = eng.run(reqs.clone()).unwrap();
        for (id, out) in &base.outputs {
            assert_eq!(
                out,
                &r.outputs[id],
                "drafter {} diverged from vanilla on request {id}",
                drafter.name()
            );
        }
    }
}

#[test]
fn unified_schedule_lossless_and_flatter() {
    let rt = runtime();
    let reqs = small_requests(&rt, 6, 40);
    let mut lock = Engine::new(
        rt.clone(),
        EngineConfig::new(DrafterKind::Pillar { w: 64 })
            .with_k(8)
            .with_schedule(Schedule::Lockstep, false),
    )
    .unwrap();
    let rl = lock.run(reqs.clone()).unwrap();
    let mut uni = Engine::new(
        rt.clone(),
        EngineConfig::new(DrafterKind::Pillar { w: 64 })
            .with_k(8)
            .with_schedule(Schedule::Unified, false),
    )
    .unwrap();
    let ru = uni.run(reqs.clone()).unwrap();
    for (id, out) in &rl.outputs {
        assert_eq!(out, &ru.outputs[id], "unified schedule changed output {id}");
    }
    // The point of unified scheduling: a flatter GEMM-row trace.
    assert!(
        ru.trace.gemm_rows_stddev() < rl.trace.gemm_rows_stddev(),
        "unified {} !< lockstep {}",
        ru.trace.gemm_rows_stddev(),
        rl.trace.gemm_rows_stddev()
    );
}

#[test]
fn delayed_verification_lossless() {
    let rt = runtime();
    let reqs = small_requests(&rt, 4, 40);
    let mut sync = Engine::new(
        rt.clone(),
        EngineConfig::new(DrafterKind::Pillar { w: 64 })
            .with_k(8)
            .with_schedule(Schedule::Unified, false),
    )
    .unwrap();
    let rs = sync.run(reqs.clone()).unwrap();
    let mut delayed = Engine::new(
        rt.clone(),
        EngineConfig::new(DrafterKind::Pillar { w: 64 })
            .with_k(8)
            .with_schedule(Schedule::Unified, true),
    )
    .unwrap();
    let rd = delayed.run(reqs.clone()).unwrap();
    for (id, out) in &rs.outputs {
        assert_eq!(out, &rd.outputs[id], "delayed verification changed output {id}");
    }
    // Overlap must not increase the simulated CPU critical path.
    assert!(rd.sim_cpu_s <= rs.sim_cpu_s + 1e-6);
}

#[test]
fn kv_offload_roundtrip_preserves_output() {
    let rt = runtime();
    let m = &rt.cfg.model;
    let reqs = small_requests(&rt, 8, 56);
    // Unbounded budget reference.
    let mut free = Engine::new(
        rt.clone(),
        EngineConfig::new(DrafterKind::Pillar { w: 64 }).with_k(8),
    )
    .unwrap();
    let rf = free.run(reqs.clone()).unwrap();
    // Tight budget forces offloads mid-run.
    let budget = m.slots * m.max_seq / 16;
    let mut tight = Engine::new(
        rt.clone(),
        EngineConfig::new(DrafterKind::Pillar { w: 64 })
            .with_k(8)
            .with_kv(KvPolicy::Dynamic, budget),
    )
    .unwrap();
    let rt_ = tight.run(reqs.clone()).unwrap();
    assert!(rt_.kv.offload_events > 0, "budget never pressured — test is vacuous");
    assert_eq!(rt_.kv.recomputed_tokens, 0, "dynamic policy must never recompute");
    assert_eq!(rf.requests_done, rt_.requests_done);
    for (id, out) in &rf.outputs {
        assert_eq!(out, &rt_.outputs[id], "offload roundtrip corrupted request {id}");
    }
}

#[test]
fn preempt_policy_recomputes_but_stays_correct() {
    let rt = runtime();
    let m = &rt.cfg.model;
    let reqs = small_requests(&rt, 8, 48);
    let mut free = Engine::new(
        rt.clone(),
        EngineConfig::new(DrafterKind::Pillar { w: 64 }).with_k(8),
    )
    .unwrap();
    let rf = free.run(reqs.clone()).unwrap();
    let budget = m.slots * m.max_seq / 16;
    let mut eng = Engine::new(
        rt.clone(),
        EngineConfig::new(DrafterKind::Pillar { w: 64 })
            .with_k(8)
            .with_kv(KvPolicy::Preempt, budget),
    )
    .unwrap();
    let r = eng.run(reqs.clone()).unwrap();
    assert!(r.kv.recomputed_tokens > 0, "budget never pressured — test is vacuous");
    assert_eq!(r.requests_done, rf.requests_done);
    for (id, out) in &rf.outputs {
        assert_eq!(out, &r.outputs[id], "preemption corrupted request {id}");
    }
}

#[test]
fn stochastic_mode_runs_and_accepts() {
    let rt = runtime();
    let mut cfg = EngineConfig::new(DrafterKind::Pillar { w: 64 }).with_k(8);
    cfg.temperature = 0.65; // the paper's sampling temperature
    let mut eng = Engine::new(rt.clone(), cfg).unwrap();
    let r = eng.run(small_requests(&rt, 3, 40)).unwrap();
    assert_eq!(r.requests_done, 3);
    assert!(r.accept.alpha() > 0.05, "stochastic acceptance collapsed");
    for out in r.outputs.values() {
        assert!(out.iter().all(|&t| t >= 0 && (t as usize) < rt.cfg.model.vocab));
    }
}

#[test]
fn sensitivity_variants_load() {
    // Every artifact variant referenced by the Fig. 12 sweeps must load
    // and execute.
    let rt = runtime();
    for q in rt.cfg.model.verify_q_variants.clone() {
        rt.executable(&format!("verify_q{q}")).unwrap();
    }
    for w in rt.cfg.model.draft_w_variants.clone() {
        rt.executable(&format!("draft_w{w}")).unwrap();
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn pallas_compose_proof_artifacts_match_ref_path() {
    // The pallas-lowered artifacts must produce the same numerics as the
    // ref-path artifacts the engine serves with (compose proof).
    let rt = runtime();
    let m = rt.cfg.model.clone();
    let mut runner = ModelRunner::new(rt.clone()).unwrap();
    let s = m.slots;
    // Build a tiny context then compare one draft step on both paths.
    let prompt: Vec<i32> = (0..8).map(|i| 16 + i as i32).collect();
    let mut tokens = vec![0i32; s * m.prompt_pad];
    for (j, &t) in prompt.iter().enumerate() {
        tokens[j] = t;
    }
    let mut plen = vec![1i32; s];
    plen[0] = prompt.len() as i32;
    let active: Vec<i32> = (0..s).map(|i| if i == 0 { 1 } else { 0 }).collect();
    runner.prefill(&tokens, &plen, &active).unwrap();

    let w = m.draft_budget;
    let mut idx = vec![-1i32; s * m.layers * m.kv_heads * w];
    for lh in 0..(m.layers * m.kv_heads) {
        for j in 0..9 {
            idx[lh * w + j] = j as i32;
        }
    }
    let token = vec![7i32; s];
    let pos = vec![8i32; s];
    // ref path artifact (arena-resident after the fill call)
    runner.draft(w, &token, &pos, &idx, &active).unwrap();
    let ref_logits = runner.logits().to_vec();
    // pallas path artifact — same inputs, direct execute
    let rtc = runner.rt.clone();
    let weights = {
        let dirp = std::path::Path::new(&rtc.cfg.dir).join("weights.bin");
        Runtime::read_f32_file(&dirp).unwrap()
    };
    let wbuf = rtc.upload_f32(&weights, &[weights.len()]).unwrap();
    let dims = [m.layers, m.slots, m.max_seq, m.kv_heads, m.head_dim];
    let zeros = vec![0f32; m.kv_pool_elems()];
    let kvk = rtc.upload_f32(&zeros, &dims).unwrap();
    let kvv = rtc.upload_f32(&zeros, &dims).unwrap();
    // replay prefill on the fresh pools via the pallas prefill? prefill has
    // no pallas variant; reuse ref prefill then pallas draft.
    let tok_b = rtc.upload_i32(&tokens, &[s, m.prompt_pad]).unwrap();
    let plen_b = rtc.upload_i32(&plen, &[s]).unwrap();
    let act_b = rtc.upload_i32(&active, &[s]).unwrap();
    let out = rtc
        .execute("prefill", &[&wbuf, &kvk, &kvv, &tok_b, &plen_b, &act_b])
        .unwrap();
    let (kvk, kvv) = (&out[1], &out[2]);
    let tok_b = rtc.upload_i32(&token, &[s]).unwrap();
    let pos_b = rtc.upload_i32(&pos, &[s]).unwrap();
    let idx_b = rtc
        .upload_i32(&idx, &[s, m.layers, m.kv_heads, w])
        .unwrap();
    let out2 = rtc
        .execute("draft_pallas", &[&wbuf, kvk, kvv, &tok_b, &pos_b, &idx_b, &act_b])
        .unwrap();
    let logits_pallas = rtc.fetch_f32(&out2[0]).unwrap();
    let max_diff = ref_logits
        .iter()
        .zip(logits_pallas.iter())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-3, "pallas vs ref artifact diverged: {max_diff}");
}

// ---------------------------------------------------------------------
// PillarAttn selection pipeline (artifact-free: pure CPU cross-module)
// ---------------------------------------------------------------------

/// The engine-shaped selection flow — refresh from a multi-head dump
/// (serial and threadpool-parallel), then compose straight into a
/// flattened [L, Hkv, W] index buffer — must be deterministic, identical
/// across the two refresh paths, and -1-disciplined like the artifacts
/// expect.
#[test]
fn pillar_selection_pipeline_parallel_and_flat_buffer() {
    use sparsespec::spec::{IndexPolicy, PillarState};
    use sparsespec::util::rng::Xoshiro256;
    use sparsespec::util::threadpool::ThreadPool;

    let (layers, kv_heads, w, t_dim) = (4usize, 2usize, 32usize, 256usize);
    let pol = IndexPolicy::pillar(w);
    let mut rng = Xoshiro256::new(1234);
    let dump: Vec<f32> = (0..layers * kv_heads * t_dim)
        .map(|_| rng.unit() as f32)
        .collect();
    let pool = ThreadPool::new(3);

    let mut serial = PillarState::new(layers, kv_heads, pol);
    let mut par = PillarState::new(layers, kv_heads, pol);
    let per_slot = layers * kv_heads * w;
    let mut idxs_a = vec![0i32; per_slot];
    let mut idxs_b = vec![0i32; per_slot];
    for round in 0..8usize {
        let len = 32 + round * 28;
        serial.refresh_from(&dump, t_dim, len);
        par.refresh_parallel(&dump, t_dim, len, &pool);
        // compose at len+1 like draft_step does after the KV write
        serial.compose_into(&mut idxs_a, len + 1);
        par.compose_into(&mut idxs_b, len + 1);
        assert_eq!(idxs_a, idxs_b, "round {round}");
        for lh in 0..layers * kv_heads {
            let row = &idxs_a[lh * w..(lh + 1) * w];
            let n_valid = row.iter().filter(|&&x| x >= 0).count();
            // valid ascending prefix, -1 tail, newest position present
            assert!(row[..n_valid].windows(2).all(|p| p[0] < p[1]), "{row:?}");
            assert!(row[n_valid..].iter().all(|&x| x == -1));
            assert!(row[..n_valid].contains(&(len as i32)), "newest missing: {row:?}");
            assert_eq!(n_valid, w.min(len + 1));
        }
    }
}
