//! Execution runtimes: the Layer-3 ↔ model bridge behind one API.
//!
//! Two interchangeable backends provide the same `Runtime` + `ModelRunner`
//! surface the engine is written against:
//!
//! * **`pjrt`** (cargo feature, [`pjrt`] module): the real path — loads the
//!   AOT HLO artifacts produced by `python/compile/aot.py` through the
//!   vendored, patched `xla` crate and executes them on the PJRT CPU
//!   client with device-resident weights and KV pools.  Needs
//!   `make artifacts` and the vendored sources under `rust/vendor/xla`.
//! * **default** ([`sim`] module): a deterministic CPU fallback that keeps
//!   every *system-level* contract of the artifacts — KV pool layout,
//!   causal visibility, sparse index-set visibility, verification score
//!   dumps, greedy losslessness — while replacing the transformer numerics
//!   with a seeded hash model.  It needs no artifacts and no native deps,
//!   so `cargo build && cargo test` work from a fresh checkout (CI tier-1),
//!   and engine/scheduler/KV behaviour is bit-reproducible across machines.
//!
//! Code that only serves requests (engine, examples, benches) compiles
//! identically against either; raw artifact execution (`Runtime::execute`)
//! and the Pallas compose-proof paths are `pjrt`-only.

mod arena;
mod stats;

pub use arena::{ArtifactNames, StepArena};
pub use stats::{PhaseTimes, StepStats};

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
mod runner;
#[cfg(feature = "pjrt")]
pub use self::{pjrt::Runtime, runner::ModelRunner};

#[cfg(not(feature = "pjrt"))]
mod sim;
#[cfg(not(feature = "pjrt"))]
pub use sim::reference;
#[cfg(not(feature = "pjrt"))]
pub use sim::{Artifact, Buffer, ModelRunner, Runtime};
