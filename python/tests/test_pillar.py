# Critical-token identification: python reference vs the pinned semantics
# shared with rust/src/spec/pillar.rs.
import numpy as np

from compile.kernels.ref import topk_ids_ref


def test_topk_selects_highest_mass():
    dump = np.zeros((2, 64), np.float32)
    dump[0, 30] = 0.9
    dump[0, 45] = 0.8
    dump[1, 10] = 0.7
    ids = topk_ids_ref(dump, length=64, budget=16, recent=4, sinks=2)
    assert ids.shape == (2, 16)
    assert 30 in ids[0] and 45 in ids[0]
    assert 10 in ids[1]
    for h in range(2):
        assert 0 in ids[h] and 1 in ids[h]           # sinks
        for t in range(60, 64):                      # recent
            assert t in ids[h]


def test_topk_short_context_padding():
    dump = np.full((1, 32), 0.1, np.float32)
    ids = topk_ids_ref(dump, length=5, budget=16, recent=4, sinks=2)
    valid = ids[0][ids[0] >= 0]
    np.testing.assert_array_equal(valid, [0, 1, 2, 3, 4])
    assert (ids[0][5:] == -1).all()


def test_topk_ascending_unique_in_range():
    rng = np.random.default_rng(0)
    for _ in range(25):
        hkv = rng.integers(1, 3)
        t = int(rng.integers(16, 256))
        length = int(rng.integers(0, t))
        budget = int(rng.integers(4, 64))
        recent = int(rng.integers(1, budget))
        sinks = int(rng.integers(0, max(budget - recent, 1)))
        dump = rng.random((hkv, t)).astype(np.float32)
        ids = topk_ids_ref(dump, length, budget, recent, sinks)
        for h in range(hkv):
            valid = ids[h][ids[h] >= 0]
            assert len(valid) == min(budget, length)
            assert (np.diff(valid) > 0).all() if len(valid) > 1 else True
            assert (valid < max(length, 1)).all()
            if length > 0 and budget > 0:
                assert (length - 1) in valid  # newest position always kept


def test_topk_cross_language_pinned_case():
    """Exact case mirrored in rust/src/spec/pillar.rs tests: sinks=2,
    recent=4, budget=16 over scores with spikes at 30/45/10 of len 64."""
    dump = np.zeros((1, 64), np.float32)
    for t, s in [(30, 0.9), (45, 0.8), (10, 0.7), (20, 0.6)]:
        dump[0, t] = s
    ids = topk_ids_ref(dump, 64, 12, 4, 2)
    # sinks 0,1 + recent 60..63 + top-6 of the rest by mass then index
    expect = [0, 1, 10, 20, 30, 45, 60, 61, 62, 63]
    for e in expect:
        assert e in ids[0], f"{e} missing from {ids[0]}"
